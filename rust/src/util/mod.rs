//! In-tree substrates for the offline environment (DESIGN.md §3):
//! errors, JSON, CLI parsing, PRNG, micro-benchmarking, property testing,
//! deterministic fault injection and the scoped data-parallel thread pool.

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod error;
pub mod failpoint;
pub mod json;
pub mod prng;
pub mod proptest;
pub mod threadpool;
