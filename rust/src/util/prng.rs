//! Deterministic PRNG (substrate — the `rand` crate is unavailable).
//!
//! xoshiro256** for uniform u64s, with helpers for floats, Gaussians
//! (Box–Muller) and Haar-uniform rotations (via unit quaternions). Used by
//! the MD thermostat, the LEE harness, workload generators and the
//! property-testing kit. Deterministic in the seed: every experiment is
//! reproducible from its config.

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Gaussian from Box-Muller
    spare: Option<f64>,
}

/// Serialisable snapshot of the *complete* generator state
/// ([`Rng::state`] / [`Rng::from_state`]): the xoshiro256** word state plus
/// the cached Box–Muller spare. Checkpoints must capture both — dropping
/// the spare desynchronises every Gaussian draw after a resume.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub spare: Option<f64>,
}

impl Rng {
    /// Snapshot the full state for checkpointing.
    pub fn state(&self) -> RngState {
        RngState { s: self.s, spare: self.spare }
    }

    /// Rebuild a generator that continues bit-identically from a snapshot.
    pub fn from_state(st: RngState) -> Self {
        Rng { s: st.s, spare: st.spare }
    }
    /// Seed via SplitMix64 expansion (any u64 is a fine seed, incl. 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift reduction with
    /// rejection of the biased low band — exactly uniform for every n, unlike
    /// the naive `next_u64() % n` (which over-weights small residues whenever
    /// n does not divide 2^64).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n64 = n as u64;
        let mut m = (self.next_u64() as u128) * (n64 as u128);
        let mut lo = m as u64;
        if lo < n64 {
            // reject draws in the short first bucket: 2^64 mod n values map
            // to it once more than to every other residue
            let threshold = n64.wrapping_neg() % n64;
            while lo < threshold {
                m = (self.next_u64() as u128) * (n64 as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (caches the pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Haar-uniform rotation matrix (Shoemake: normalised Gaussian quaternion).
    pub fn rotation(&mut self) -> [[f64; 3]; 3] {
        let q = [self.gaussian(), self.gaussian(), self.gaussian(), self.gaussian()];
        let n = (q[0] * q[0] + q[1] * q[1] + q[2] * q[2] + q[3] * q[3]).sqrt();
        let (w, x, y, z) = (q[0] / n, q[1] / n, q[2] / n, q[3] / n);
        [
            [1.0 - 2.0 * (y * y + z * z), 2.0 * (x * y - w * z), 2.0 * (x * z + w * y)],
            [2.0 * (x * y + w * z), 1.0 - 2.0 * (x * x + z * z), 2.0 * (y * z - w * x)],
            [2.0 * (x * z - w * y), 2.0 * (y * z + w * x), 1.0 - 2.0 * (x * x + y * y)],
        ]
    }

    /// Uniform unit vector on S^2.
    pub fn unit_vec(&mut self) -> [f64; 3] {
        loop {
            let v = [self.gaussian(), self.gaussian(), self.gaussian()];
            let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
            if n > 1e-12 {
                return [v[0] / n, v[1] / n, v[2] / n];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_resumes_bit_identically() {
        // snapshot mid Box–Muller pair so the cached spare is in play
        let mut a = Rng::new(13);
        for _ in 0..7 {
            a.gaussian();
        }
        let snap = a.state();
        assert!(snap.spare.is_some(), "odd draw count must leave a cached spare");
        let mut b = Rng::from_state(snap);
        for _ in 0..100 {
            assert_eq!(a.gaussian().to_bits(), b.gaussian().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 20000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 40000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_deterministic_and_in_range() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for n in [1usize, 2, 3, 7, 100, 1 << 20] {
            for _ in 0..200 {
                let x = a.below(n);
                assert_eq!(x, b.below(n));
                assert!(x < n);
            }
        }
    }

    #[test]
    fn below_is_unbiased_on_small_range() {
        // Lemire reduction: each residue equally likely (the old modulo
        // reduction passes this too at n=3, but the determinism fixture above
        // pins the new draw sequence).
        let mut r = Rng::new(5);
        let n = 30_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[r.below(3)] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 1.0 / 3.0).abs() < 0.02, "counts {counts:?}");
        }
    }

    #[test]
    fn rotation_is_orthogonal() {
        let mut r = Rng::new(3);
        for _ in 0..20 {
            let m = r.rotation();
            // R R^T = I
            for i in 0..3 {
                for j in 0..3 {
                    let dot: f64 = (0..3).map(|k| m[i][k] * m[j][k]).sum();
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((dot - want).abs() < 1e-10);
                }
            }
            // det = +1
            let det = m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
                - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
                + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0]);
            assert!((det - 1.0).abs() < 1e-10);
        }
    }
}
