//! Property-testing kit (substrate — the proptest crate is unavailable).
//!
//! Deterministic random-input property checks with shrinking-free minimal
//! reporting: on failure we print the seed and case index so the exact
//! input regenerates. Used by the coordinator/quant/md invariant tests.

use super::prng::Rng;

/// Run `prop` on `cases` random inputs drawn by `gen`. Panics with the
/// reproducing (seed, case) on the first violation.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Convenience: assert with context inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(
            "addition commutes",
            1,
            200,
            |r| (r.below(1000) as i64, r.below(1000) as i64),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics() {
        check("always fails", 2, 10, |r| r.below(10), |_| Err("nope".into()));
    }
}
