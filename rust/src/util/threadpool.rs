//! Scoped data-parallel executor (substrate — rayon is unavailable).
//!
//! A zero-dependency fork-join pool for the three hot layers (quant GEMMs,
//! batched reference inference, the classical nonbonded loop). Design
//! (DESIGN.md §8):
//!
//! * **Scoped**: every parallel region runs under [`std::thread::scope`], so
//!   workers may borrow the caller's stack (no `'static` bounds, no unsafe
//!   lifetime erasure) and are always joined before the region returns.
//! * **Work-stealing-lite**: dynamic self-scheduling over a shared atomic
//!   task cursor ([`ThreadPool::for_each`] / [`ThreadPool::map`]) gives the
//!   load-balancing benefit of stealing without deques; statically
//!   partitioned row blocks ([`ThreadPool::for_each_row_block`]) serve the
//!   kernels whose output must be sharded into disjoint `&mut` slices.
//! * **Sized once**: [`ThreadPool::global`] reads `GAQ_THREADS` (a positive
//!   integer; `0`/unset/garbage falls back to
//!   `std::thread::available_parallelism`). Explicit [`ThreadPool::new`]
//!   pools let tests and benches pin serial-vs-parallel comparisons without
//!   touching the environment.
//!
//! Determinism contract: [`ThreadPool::map`] returns results in task-index
//! order regardless of which worker ran what, and a pool of one thread
//! executes tasks inline in index order — callers that reduce partials in
//! index order are therefore bit-identical for every pool size.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Fork-join executor with a fixed worker budget (see module docs).
#[derive(Debug)]
pub struct ThreadPool {
    threads: usize,
}

/// Pool observability handles (forking regions only — the inline fast paths
/// stay untouched, their time is attributed to the caller's own spans).
struct PoolObs {
    regions: &'static crate::obs::Counter,
    tasks: &'static crate::obs::Counter,
    busy_ns: &'static crate::obs::Counter,
    idle_ns: &'static crate::obs::Counter,
    region_ns: &'static crate::obs::LogHistogram,
    region_span: u32,
    worker_span: u32,
}

fn pool_obs() -> &'static PoolObs {
    static OBS: OnceLock<PoolObs> = OnceLock::new();
    OBS.get_or_init(|| PoolObs {
        regions: crate::obs::counter("threadpool_regions"),
        tasks: crate::obs::counter("threadpool_tasks"),
        busy_ns: crate::obs::counter("threadpool_busy_ns"),
        idle_ns: crate::obs::counter("threadpool_idle_ns"),
        region_ns: crate::obs::histogram("threadpool_region_ns"),
        region_span: crate::obs::span::intern("pool_region"),
        worker_span: crate::obs::span::intern("pool_worker"),
    })
}

impl PoolObs {
    /// Open the caller-side region span and count the fork.
    fn enter_region(&'static self, tasks: usize) -> crate::obs::SpanGuard {
        self.regions.inc();
        self.tasks.add(tasks as u64);
        crate::obs::SpanGuard::enter_timed(self.region_span, self.region_ns)
    }

    /// Credit busy time against the region's wall clock: idle is the gap
    /// between `workers x wall` and the summed per-worker busy time.
    fn settle(&'static self, workers: usize, wall_ns: u64, busy: &AtomicU64) {
        let busy = busy.load(Ordering::Relaxed);
        self.busy_ns.add(busy);
        self.idle_ns.add((workers as u64 * wall_ns).saturating_sub(busy));
    }
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

thread_local! {
    /// Set on pool worker threads. Nested parallel regions run inline on
    /// the worker instead of spawning again — otherwise a pooled batch
    /// whose items each shard their own inner loop would spawn threads^2
    /// OS threads. The fixed-order contracts make the serialised nested
    /// region bit-identical, so this is purely a scheduling guard.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// True when called from inside a [`ThreadPool`] worker (nested parallel
/// regions degrade to inline execution there).
pub fn is_pool_worker() -> bool {
    IN_POOL_WORKER.with(|flag| flag.get())
}

/// Thread budget from the environment: `GAQ_THREADS` if it parses to a
/// positive integer, else `available_parallelism` (1 when unknown).
pub fn configured_threads() -> usize {
    let from_env = std::env::var("GAQ_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1);
    from_env.unwrap_or_else(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

impl ThreadPool {
    /// A pool with an explicit worker budget (clamped to >= 1).
    pub fn new(threads: usize) -> ThreadPool {
        ThreadPool { threads: threads.max(1) }
    }

    /// The process-wide pool, sized from `GAQ_THREADS` /
    /// `available_parallelism` on first use (the env var is read once).
    pub fn global() -> &'static ThreadPool {
        GLOBAL.get_or_init(|| ThreadPool::new(configured_threads()))
    }

    /// Worker budget of this pool.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(i)` for every `i in 0..n`, self-scheduled across the pool.
    /// With one worker (or one task) everything runs inline, in order.
    pub fn for_each<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let workers = if is_pool_worker() { 1 } else { self.threads.min(n) };
        if workers <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let obs = pool_obs();
        let region = obs.enter_region(n);
        let region_id = region.id();
        let t0 = crate::obs::span::now_ns();
        let busy = AtomicU64::new(0);
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    IN_POOL_WORKER.with(|flag| flag.set(true));
                    let _w = crate::obs::SpanGuard::enter_with_parent(obs.worker_span, region_id);
                    let w0 = crate::obs::span::now_ns();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        f(i);
                    }
                    busy.fetch_add(crate::obs::span::now_ns() - w0, Ordering::Relaxed);
                });
            }
        });
        obs.settle(workers, crate::obs::span::now_ns() - t0, &busy);
    }

    /// Run `f(i)` for every `i in 0..n` and collect the results **in task
    /// order** — the returned vector is independent of scheduling.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let workers = if is_pool_worker() { 1 } else { self.threads.min(n) };
        if workers <= 1 {
            return (0..n).map(&f).collect();
        }
        let obs = pool_obs();
        let region = obs.enter_region(n);
        let region_id = region.id();
        let t0 = crate::obs::span::now_ns();
        let busy = AtomicU64::new(0);
        let next = AtomicUsize::new(0);
        let parts: Vec<Vec<(usize, T)>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    s.spawn(|| {
                        IN_POOL_WORKER.with(|flag| flag.set(true));
                        let _w =
                            crate::obs::SpanGuard::enter_with_parent(obs.worker_span, region_id);
                        let w0 = crate::obs::span::now_ns();
                        let mut out = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            out.push((i, f(i)));
                        }
                        busy.fetch_add(crate::obs::span::now_ns() - w0, Ordering::Relaxed);
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("pool worker panicked"))
                .collect()
        });
        obs.settle(workers, crate::obs::span::now_ns() - t0, &busy);
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        for part in parts {
            for (i, v) in part {
                slots[i] = Some(v);
            }
        }
        slots.into_iter().map(|o| o.expect("pool map slot unfilled")).collect()
    }

    /// Shard `data` (a row-major matrix with rows of `row_len` elements)
    /// into one contiguous block of whole rows per worker and run
    /// `f(first_row, block)` on each block concurrently. Blocks are
    /// disjoint `&mut` slices, so kernels write their shard directly.
    pub fn for_each_row_block<T, F>(&self, data: &mut [T], row_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(row_len > 0, "row_len must be positive");
        assert_eq!(data.len() % row_len, 0, "data is not a whole number of rows");
        let rows = data.len() / row_len;
        let workers = if is_pool_worker() { 1 } else { self.threads.min(rows) };
        if workers <= 1 {
            if !data.is_empty() {
                f(0, data);
            }
            return;
        }
        let obs = pool_obs();
        let region = obs.enter_region(workers);
        let region_id = region.id();
        let t0 = crate::obs::span::now_ns();
        let busy = AtomicU64::new(0);
        let rows_per = rows.div_ceil(workers);
        std::thread::scope(|s| {
            for (b, block) in data.chunks_mut(rows_per * row_len).enumerate() {
                let f = &f;
                let busy = &busy;
                s.spawn(move || {
                    IN_POOL_WORKER.with(|flag| flag.set(true));
                    let _w = crate::obs::SpanGuard::enter_with_parent(obs.worker_span, region_id);
                    let w0 = crate::obs::span::now_ns();
                    f(b * rows_per, block);
                    busy.fetch_add(crate::obs::span::now_ns() - w0, Ordering::Relaxed);
                });
            }
        });
        obs.settle(workers, crate::obs::span::now_ns() - t0, &busy);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn new_clamps_to_one_worker() {
        assert_eq!(ThreadPool::new(0).threads(), 1);
        assert_eq!(ThreadPool::new(3).threads(), 3);
    }

    #[test]
    fn global_pool_has_at_least_one_worker() {
        assert!(ThreadPool::global().threads() >= 1);
    }

    #[test]
    fn map_preserves_task_order_for_every_pool_size() {
        let want: Vec<usize> = (0..97).map(|i| i * i).collect();
        for threads in [1, 2, 3, 8] {
            let pool = ThreadPool::new(threads);
            let got = pool.map(97, |i| i * i);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn for_each_runs_every_task_exactly_once() {
        for threads in [1, 4] {
            let pool = ThreadPool::new(threads);
            let hits: Vec<AtomicUsize> = (0..211).map(|_| AtomicUsize::new(0)).collect();
            pool.for_each(hits.len(), |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} (threads={threads})");
            }
        }
    }

    #[test]
    fn row_blocks_cover_all_rows_disjointly() {
        for threads in [1, 2, 5] {
            let pool = ThreadPool::new(threads);
            let (rows, row_len) = (13usize, 7usize);
            let mut data = vec![0usize; rows * row_len];
            let seen = Mutex::new(Vec::new());
            pool.for_each_row_block(&mut data, row_len, |first_row, block| {
                assert_eq!(block.len() % row_len, 0);
                for x in block.iter_mut() {
                    *x += 1;
                }
                seen.lock().unwrap().push((first_row, block.len() / row_len));
            });
            assert!(data.iter().all(|&x| x == 1), "threads={threads}");
            let mut ranges = seen.into_inner().unwrap();
            ranges.sort_unstable();
            let covered: usize = ranges.iter().map(|&(_, n)| n).sum();
            assert_eq!(covered, rows);
        }
    }

    #[test]
    fn nested_regions_run_inline_on_worker_threads() {
        let outer = ThreadPool::new(4);
        let results = outer.map(8, |i| {
            // we are on an outer-region worker thread...
            let on_worker = is_pool_worker();
            // ...so the inner region must degrade to inline execution
            // (still on this worker) instead of spawning again
            let inner = ThreadPool::new(4);
            let inner_flags = inner.map(4, |_| is_pool_worker());
            (i, on_worker, inner_flags)
        });
        for (i, on_worker, inner_flags) in results {
            assert!(on_worker, "task {i} did not run on a pool worker");
            assert!(
                inner_flags.iter().all(|&w| w),
                "task {i}: nested tasks left the worker thread"
            );
        }
        // back on the caller thread the flag must be clear
        assert!(!is_pool_worker());
    }

    #[test]
    fn empty_and_tiny_inputs_are_fine() {
        let pool = ThreadPool::new(4);
        assert_eq!(pool.map(0, |i| i), Vec::<usize>::new());
        pool.for_each(0, |_| panic!("no tasks expected"));
        let mut empty: [f32; 0] = [];
        pool.for_each_row_block(&mut empty, 3, |_, _| panic!("no rows expected"));
        assert_eq!(pool.map(1, |i| i + 41), vec![41]);
    }
}
