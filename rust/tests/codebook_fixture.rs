//! Python <-> Rust octahedral-codebook agreement (the cross-check promised
//! in rust/src/quant/codebook.rs): both implementations must map the same
//! unit vectors to the same grid codes and codewords. The checked-in fixture
//! (fixtures/oct_codebook.json, regenerate with
//! fixtures/gen_oct_codebook_fixture.py) is consumed here and by
//! python/tests/test_codebook_fixture.py.

use gaq_md::quant::codebook::{oct_decode, oct_encode, oct_quantize};
use gaq_md::util::json;

fn fixture() -> json::Json {
    let path = gaq_md::workspace_root().join("fixtures").join("oct_codebook.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    json::parse(&text).expect("fixture is valid json")
}

fn vec3(j: &json::Json) -> [f64; 3] {
    let a = j.as_arr().expect("vec3 array");
    assert_eq!(a.len(), 3);
    [
        a[0].as_f64().unwrap(),
        a[1].as_f64().unwrap(),
        a[2].as_f64().unwrap(),
    ]
}

#[test]
fn oct_codebook_agrees_with_checked_in_fixture() {
    let j = fixture();
    let bits = j.get("bits").and_then(|b| b.as_usize()).expect("bits") as u32;
    let cases = j.get("cases").and_then(|c| c.as_arr()).expect("cases");
    assert!(cases.len() >= 32, "fixture unexpectedly small: {}", cases.len());

    for (i, case) in cases.iter().enumerate() {
        let u = vec3(case.get("u").expect("u"));
        let gx = case.get("gx").and_then(|v| v.as_usize()).expect("gx") as u32;
        let gy = case.get("gy").and_then(|v| v.as_usize()).expect("gy") as u32;
        let q = vec3(case.get("q").expect("q"));

        let (egx, egy) = oct_encode(u, bits);
        assert_eq!(
            (egx, egy),
            (gx, gy),
            "case {i}: encode({u:?}) = ({egx}, {egy}), fixture says ({gx}, {gy})"
        );

        let dec = oct_decode(gx, gy, bits);
        for ax in 0..3 {
            assert!(
                (dec[ax] - q[ax]).abs() < 1e-9,
                "case {i} axis {ax}: decoded {} vs fixture {}",
                dec[ax],
                q[ax]
            );
        }

        // quantise(u) is the composition — must land exactly on the codeword
        let qq = oct_quantize(u, bits);
        for ax in 0..3 {
            assert!((qq[ax] - q[ax]).abs() < 1e-9, "case {i}: quantize != decode∘encode");
        }
    }
}
