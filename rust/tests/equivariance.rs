//! Metamorphic SO(3) equivariance suite (EGNN-style property tests) over
//! every variant in the builtin manifest.
//!
//! Metamorphic relations, checked under Haar-random rotations at randomly
//! perturbed configurations over many seeds:
//!
//! 1. **Energy invariance** — E(R r) == E(r) up to f32 casting noise, for
//!    every variant (energies are never quantized).
//! 2. **Force equivariance** — mean_i ||f(R r)_i - R f(r)_i|| stays below a
//!    per-variant cap.
//! 3. **LEE ordering** (the paper's Table III law) —
//!    fp32 < gaq < degree < naive, as a property of the aggregated means.
//! 4. **Serial/parallel agreement** — every evaluation is computed on both
//!    the serial single path and the pooled batch path, and the two must be
//!    bit-identical (the suite runs each relation on both paths at once).

use std::collections::BTreeMap;

use gaq_md::geometry::matvec;
use gaq_md::runtime::{ExecBackend, Manifest, ReferenceForceField};
use gaq_md::util::prng::Rng;
use gaq_md::util::threadpool::ThreadPool;

fn rotate(positions: &[f64], rot: &[[f64; 3]; 3]) -> Vec<f64> {
    let mut out = positions.to_vec();
    for c in out.chunks_exact_mut(3) {
        let v = matvec(rot, [c[0], c[1], c[2]]);
        c.copy_from_slice(&v);
    }
    out
}

fn to_f32(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

/// Evaluate one metamorphic probe: returns (mean force LEE eV/A, |dE| eV).
/// Both configurations are evaluated twice — serially and as a pooled
/// batch — and the two paths must agree bit-for-bit.
fn lee_once(
    ff: &ReferenceForceField,
    pos: &[f64],
    rot: &[[f64; 3]; 3],
    pool: &ThreadPool,
) -> (f64, f64) {
    let rpos = rotate(pos, rot);
    let batch = vec![to_f32(pos), to_f32(&rpos)];

    let (e0, f0) = ff.energy_forces_f32(&batch[0]).expect("serial eval");
    let (er, fr) = ff.energy_forces_f32(&batch[1]).expect("serial eval (rotated)");

    let outs = ff.energy_forces_batch_with(&batch, pool).expect("pooled batch eval");
    assert_eq!(outs.len(), 2);
    assert_eq!(outs[0].0.to_bits(), e0.to_bits(), "parallel energy != serial");
    assert_eq!(outs[1].0.to_bits(), er.to_bits(), "parallel energy != serial (rotated)");
    assert_eq!(outs[0].1, f0, "parallel forces != serial");
    assert_eq!(outs[1].1, fr, "parallel forces != serial (rotated)");

    let n = pos.len() / 3;
    let mut total = 0.0;
    for i in 0..n {
        let want = matvec(
            rot,
            [f0[3 * i] as f64, f0[3 * i + 1] as f64, f0[3 * i + 2] as f64],
        );
        let dx = fr[3 * i] as f64 - want[0];
        let dy = fr[3 * i + 1] as f64 - want[1];
        let dz = fr[3 * i + 2] as f64 - want[2];
        total += (dx * dx + dy * dy + dz * dz).sqrt();
    }
    (total / n as f64, (er as f64 - e0 as f64).abs())
}

/// Per-variant force-LEE upper bound, eV/A. Loose caps — the sharp claim
/// is the ordering property, asserted separately.
fn lee_cap(name: &str) -> f64 {
    let key = name.to_ascii_lowercase();
    if key.contains("fp32") {
        1e-3 // f32 casting noise only
    } else if key.contains("gaq") {
        0.05 // invariant magnitudes + oct-12 directions
    } else if key.contains("degree") {
        0.3 // per-atom scales: partially preserved
    } else if key.contains("svq") {
        5.0 // 256-word codebook: coarse directions
    } else {
        2.0 // naive / lsq / qdrop: Cartesian INT8 grid
    }
}

#[test]
fn metamorphic_equivariance_over_all_builtin_variants() {
    let m = Manifest::reference();
    assert!(m.variants.len() >= 7, "builtin roster shrank: {}", m.variants.len());
    let pool = ThreadPool::new(4);

    let mut mean_lee: BTreeMap<String, f64> = BTreeMap::new();
    for (name, variant) in &m.variants {
        let ff = ReferenceForceField::new(variant, &m.molecule);
        let mut lee_sum = 0.0;
        let mut count = 0usize;
        for seed in 0..3u64 {
            let mut rng = Rng::new(1000 + seed);
            // perturb off equilibrium so forces (and quantisation error)
            // are non-degenerate
            let mut pos = m.molecule.positions.clone();
            for x in pos.iter_mut() {
                *x += 0.05 * rng.gaussian();
            }
            for _ in 0..5 {
                let rot = rng.rotation();
                let (lee, einv) = lee_once(&ff, &pos, &rot, &pool);
                assert!(
                    einv < 0.01,
                    "{name}: energy not rotation-invariant: |dE| = {einv} eV"
                );
                lee_sum += lee;
                count += 1;
            }
        }
        let mean = lee_sum / count as f64;
        let cap = lee_cap(name);
        assert!(
            mean < cap,
            "{name}: mean force LEE {mean:.6} eV/A exceeds cap {cap} eV/A"
        );
        mean_lee.insert(name.clone(), mean);
    }

    // the paper's LEE ordering, as a property of the seed-aggregated means
    let fp32 = mean_lee["fp32"];
    let gaq = mean_lee["gaq_w4a8"];
    let degree = mean_lee["degree_quant"];
    let naive = mean_lee["naive_int8"];
    assert!(
        fp32 < gaq && gaq < degree && degree < naive,
        "LEE ordering violated: fp32={fp32:.2e} gaq={gaq:.2e} degree={degree:.2e} naive={naive:.2e}"
    );
}

#[test]
fn batch_evaluation_is_permutation_equivariant() {
    // metamorphic relation on the batch axis: permuting the batch permutes
    // the results and changes nothing else (serial and pooled paths)
    let m = Manifest::reference();
    let ff = ReferenceForceField::new(m.variant("gaq_w4a8").unwrap(), &m.molecule);
    let mut rng = Rng::new(7);
    let base = to_f32(&m.molecule.positions);
    let batch: Vec<Vec<f32>> = (0..5)
        .map(|_| base.iter().map(|&x| x + 0.02 * rng.gaussian() as f32).collect())
        .collect();
    let perm = [3usize, 0, 4, 2, 1];
    let shuffled: Vec<Vec<f32>> = perm.iter().map(|&i| batch[i].clone()).collect();

    for pool in [ThreadPool::new(1), ThreadPool::new(4)] {
        let out = ff.energy_forces_batch_with(&batch, &pool).unwrap();
        let out_shuffled = ff.energy_forces_batch_with(&shuffled, &pool).unwrap();
        for (slot, &src) in perm.iter().enumerate() {
            assert_eq!(out_shuffled[slot].0.to_bits(), out[src].0.to_bits());
            assert_eq!(out_shuffled[slot].1, out[src].1);
        }
    }
}

#[test]
fn rotation_composition_is_consistent() {
    // metamorphic: rotating twice equals rotating by the composition —
    // guards the harness itself (a broken rotate() would silence the suite)
    let m = Manifest::reference();
    let mut rng = Rng::new(11);
    let r1 = rng.rotation();
    let r2 = rng.rotation();
    let pos = m.molecule.positions.clone();
    let once = rotate(&rotate(&pos, &r1), &r2);
    // compose: (r2 * r1)
    let mut comp = [[0f64; 3]; 3];
    for (i, row) in comp.iter_mut().enumerate() {
        for (j, cell) in row.iter_mut().enumerate() {
            *cell = (0..3).map(|k| r2[i][k] * r1[k][j]).sum();
        }
    }
    let twice = rotate(&pos, &comp);
    for (a, b) in once.iter().zip(&twice) {
        assert!((a - b).abs() < 1e-12, "{a} vs {b}");
    }
}
