//! Metamorphic SO(3) equivariance suite (EGNN-style property tests) over
//! every variant in the builtin manifest, on **both** execution backends:
//! the reference emulation and the real quantized GNN (runtime/gnn.rs).
//!
//! Metamorphic relations, checked under Haar-random rotations at randomly
//! perturbed configurations over many seeds:
//!
//! 1. **Energy invariance** — E(R r) == E(r) up to f32 casting noise, for
//!    every variant (energies are never quantized).
//! 2. **Force equivariance** — mean_i ||f(R r)_i - R f(r)_i|| stays below a
//!    per-variant cap.
//! 3. **LEE ordering** (the paper's Table III law) —
//!    fp32 < gaq < degree < naive on the reference backend, and
//!    fp32 < gaq < naive with a >= 10x gaq-vs-naive gap on the GNN backend.
//! 4. **Serial/parallel agreement** — every evaluation is computed on both
//!    the serial single path and the pooled batch path, and the two must be
//!    bit-identical (the suite runs each relation on both paths at once).
//! 5. **Layer parity** — the quantized linear layer agrees with a
//!    dequantized f32 reference on randomized shapes (the integer GEMMs
//!    compute exactly the fake-quant product).

use std::collections::BTreeMap;

use gaq_md::geometry::matvec;
use gaq_md::model::{GemmKind, QuantLinear};
use gaq_md::quant::pack::{dequantize_i8, quantize_i8};
use gaq_md::runtime::{ExecBackend, GnnForceField, Manifest, ReferenceForceField};
use gaq_md::util::error::Result;
use gaq_md::util::prng::Rng;
use gaq_md::util::threadpool::ThreadPool;

fn rotate(positions: &[f64], rot: &[[f64; 3]; 3]) -> Vec<f64> {
    let mut out = positions.to_vec();
    for c in out.chunks_exact_mut(3) {
        let v = matvec(rot, [c[0], c[1], c[2]]);
        c.copy_from_slice(&v);
    }
    out
}

fn to_f32(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

/// The two pooled-capable backends under one hat: single-path evaluation
/// from [`ExecBackend`] plus the explicit-pool batched entry point.
trait PooledBackend: ExecBackend {
    fn batch_with(&self, batch: &[Vec<f32>], pool: &ThreadPool) -> Result<Vec<(f32, Vec<f32>)>>;
}

impl PooledBackend for ReferenceForceField {
    fn batch_with(&self, batch: &[Vec<f32>], pool: &ThreadPool) -> Result<Vec<(f32, Vec<f32>)>> {
        self.energy_forces_batch_with(batch, pool)
    }
}

impl PooledBackend for GnnForceField {
    fn batch_with(&self, batch: &[Vec<f32>], pool: &ThreadPool) -> Result<Vec<(f32, Vec<f32>)>> {
        self.energy_forces_batch_with(batch, pool)
    }
}

/// Evaluate one metamorphic probe: returns (mean force LEE eV/A, |dE| eV).
/// Both configurations are evaluated twice — serially and as a pooled
/// batch — and the two paths must agree bit-for-bit.
fn lee_once(
    ff: &dyn PooledBackend,
    pos: &[f64],
    rot: &[[f64; 3]; 3],
    pool: &ThreadPool,
) -> (f64, f64) {
    let rpos = rotate(pos, rot);
    let batch = vec![to_f32(pos), to_f32(&rpos)];

    let (e0, f0) = ff.energy_forces_f32(&batch[0]).expect("serial eval");
    let (er, fr) = ff.energy_forces_f32(&batch[1]).expect("serial eval (rotated)");

    let outs = ff.batch_with(&batch, pool).expect("pooled batch eval");
    assert_eq!(outs.len(), 2);
    assert_eq!(outs[0].0.to_bits(), e0.to_bits(), "parallel energy != serial");
    assert_eq!(outs[1].0.to_bits(), er.to_bits(), "parallel energy != serial (rotated)");
    assert_eq!(outs[0].1, f0, "parallel forces != serial");
    assert_eq!(outs[1].1, fr, "parallel forces != serial (rotated)");

    let n = pos.len() / 3;
    let mut total = 0.0;
    for i in 0..n {
        let want = matvec(
            rot,
            [f0[3 * i] as f64, f0[3 * i + 1] as f64, f0[3 * i + 2] as f64],
        );
        let dx = fr[3 * i] as f64 - want[0];
        let dy = fr[3 * i + 1] as f64 - want[1];
        let dz = fr[3 * i + 2] as f64 - want[2];
        total += (dx * dx + dy * dy + dz * dz).sqrt();
    }
    (total / n as f64, (er as f64 - e0 as f64).abs())
}

/// Per-variant force-LEE upper bound, eV/A. Loose caps — the sharp claim
/// is the ordering property, asserted separately.
fn lee_cap(name: &str) -> f64 {
    let key = name.to_ascii_lowercase();
    if key.contains("fp32") {
        1e-3 // f32 casting noise only
    } else if key.contains("gaq") {
        0.05 // invariant magnitudes + oct-12 directions
    } else if key.contains("degree") {
        0.3 // per-atom scales: partially preserved
    } else if key.contains("svq") {
        5.0 // 256-word codebook: coarse directions
    } else {
        2.0 // naive / lsq / qdrop: Cartesian INT8 grid
    }
}

#[test]
fn metamorphic_equivariance_over_all_builtin_variants() {
    let m = Manifest::reference();
    assert!(m.variants.len() >= 7, "builtin roster shrank: {}", m.variants.len());
    let pool = ThreadPool::new(4);

    let mut mean_lee: BTreeMap<String, f64> = BTreeMap::new();
    for (name, variant) in &m.variants {
        let ff = ReferenceForceField::new(variant, &m.molecule);
        let mut lee_sum = 0.0;
        let mut count = 0usize;
        for seed in 0..3u64 {
            let mut rng = Rng::new(1000 + seed);
            // perturb off equilibrium so forces (and quantisation error)
            // are non-degenerate
            let mut pos = m.molecule.positions.clone();
            for x in pos.iter_mut() {
                *x += 0.05 * rng.gaussian();
            }
            for _ in 0..5 {
                let rot = rng.rotation();
                let (lee, einv) = lee_once(&ff, &pos, &rot, &pool);
                assert!(
                    einv < 0.01,
                    "{name}: energy not rotation-invariant: |dE| = {einv} eV"
                );
                lee_sum += lee;
                count += 1;
            }
        }
        let mean = lee_sum / count as f64;
        let cap = lee_cap(name);
        assert!(
            mean < cap,
            "{name}: mean force LEE {mean:.6} eV/A exceeds cap {cap} eV/A"
        );
        mean_lee.insert(name.clone(), mean);
    }

    // the paper's LEE ordering, as a property of the seed-aggregated means
    let fp32 = mean_lee["fp32"];
    let gaq = mean_lee["gaq_w4a8"];
    let degree = mean_lee["degree_quant"];
    let naive = mean_lee["naive_int8"];
    assert!(
        fp32 < gaq && gaq < degree && degree < naive,
        "LEE ordering violated: fp32={fp32:.2e} gaq={gaq:.2e} degree={degree:.2e} naive={naive:.2e}"
    );
}

#[test]
fn batch_evaluation_is_permutation_equivariant() {
    // metamorphic relation on the batch axis: permuting the batch permutes
    // the results and changes nothing else (serial and pooled paths)
    let m = Manifest::reference();
    let ff = ReferenceForceField::new(m.variant("gaq_w4a8").unwrap(), &m.molecule);
    let mut rng = Rng::new(7);
    let base = to_f32(&m.molecule.positions);
    let batch: Vec<Vec<f32>> = (0..5)
        .map(|_| base.iter().map(|&x| x + 0.02 * rng.gaussian() as f32).collect())
        .collect();
    let perm = [3usize, 0, 4, 2, 1];
    let shuffled: Vec<Vec<f32>> = perm.iter().map(|&i| batch[i].clone()).collect();

    for pool in [ThreadPool::new(1), ThreadPool::new(4)] {
        let out = ff.energy_forces_batch_with(&batch, &pool).unwrap();
        let out_shuffled = ff.energy_forces_batch_with(&shuffled, &pool).unwrap();
        for (slot, &src) in perm.iter().enumerate() {
            assert_eq!(out_shuffled[slot].0.to_bits(), out[src].0.to_bits());
            assert_eq!(out_shuffled[slot].1, out[src].1);
        }
    }
}

/// The same metamorphic relations on the **GNN backend**: a genuine
/// multi-layer quantized network rather than the post-processed oracle.
/// Asserts the acceptance law of the model subsystem: energies invariant,
/// LEE ordering fp32 < gaq < naive with LEE(gaq_w4a8) at least 10x below
/// LEE(naive_int8), every probe bit-identical between the serial and
/// pooled paths.
#[test]
fn gnn_metamorphic_equivariance_and_lee_ordering() {
    let m = Manifest::reference();
    let pool = ThreadPool::new(4);

    let mut mean_lee: BTreeMap<&str, f64> = BTreeMap::new();
    for name in ["fp32", "gaq_w4a8", "naive_int8"] {
        let ff = GnnForceField::new(&m, m.variant(name).unwrap()).unwrap();
        let mut lee_sum = 0.0;
        let mut count = 0usize;
        for seed in 0..3u64 {
            let mut rng = Rng::new(2000 + seed);
            let mut pos = m.molecule.positions.clone();
            for x in pos.iter_mut() {
                *x += 0.05 * rng.gaussian();
            }
            for _ in 0..4 {
                let rot = rng.rotation();
                let (lee, einv) = lee_once(&ff, &pos, &rot, &pool);
                // the floor is f32 noise plus (rarely) one flipped
                // quantization bin in an invariant activation (~3e-4 eV)
                assert!(
                    einv < 5e-3,
                    "{name}: GNN energy not rotation-invariant: |dE| = {einv} eV"
                );
                lee_sum += lee;
                count += 1;
            }
        }
        mean_lee.insert(name, lee_sum / count as f64);
    }

    let fp32 = mean_lee["fp32"];
    let gaq = mean_lee["gaq_w4a8"];
    let naive = mean_lee["naive_int8"];
    assert!(fp32 < 1e-5, "fp32 GNN LEE {fp32:.2e} above the f32 noise floor");
    assert!(
        fp32 < gaq && gaq < naive,
        "GNN LEE ordering violated: fp32={fp32:.2e} gaq={gaq:.2e} naive={naive:.2e}"
    );
    assert!(
        gaq * 10.0 <= naive,
        "MDDQ gap collapsed: LEE(gaq)={gaq:.2e} not 10x below LEE(naive)={naive:.2e}"
    );
}

/// Pooled GNN inference must be bit-identical to serial for every pool
/// size (the data-parallel substrate never reorders any reduction).
#[test]
fn gnn_pooled_batch_is_bit_identical_for_every_pool_size() {
    let m = Manifest::reference();
    let ff = GnnForceField::new(&m, m.variant("gaq_w4a8").unwrap()).unwrap();
    let mut rng = Rng::new(17);
    let base = to_f32(&m.molecule.positions);
    let batch: Vec<Vec<f32>> = (0..7)
        .map(|_| base.iter().map(|&x| x + 0.02 * rng.gaussian() as f32).collect())
        .collect();
    let singles: Vec<(f32, Vec<f32>)> =
        batch.iter().map(|p| ff.energy_forces_f32(p).unwrap()).collect();
    for threads in [1usize, 2, 3, 8] {
        let pool = ThreadPool::new(threads);
        let outs = ff.energy_forces_batch_with(&batch, &pool).unwrap();
        for (i, ((eb, fb), (es, fs))) in outs.iter().zip(&singles).enumerate() {
            assert_eq!(eb.to_bits(), es.to_bits(), "item {i} energy (threads={threads})");
            assert_eq!(fb, fs, "item {i} forces (threads={threads})");
        }
    }
}

/// Randomized-shape parity of the quantized linear layer against a
/// dequantized f32 reference: the integer GEMMs must compute exactly the
/// product of the fake-quantized operands (up to f32 epilogue rounding).
#[test]
fn quant_linear_matches_dequantized_reference_on_random_shapes() {
    let mut rng = Rng::new(99);
    for trial in 0..20 {
        let mm = 1 + rng.below(40);
        let k = 2 + rng.below(96);
        let n = 1 + rng.below(64); // odd n exercises the nibble-packed rows
        let w: Vec<f32> = (0..k * n).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        let a: Vec<f32> = (0..mm * k).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        let qa = quantize_i8(&a);
        let mut a_deq = vec![0f32; a.len()];
        dequantize_i8(&qa, &mut a_deq);
        for kind in [GemmKind::Int8, GemmKind::W4A8] {
            let lin = QuantLinear::new(w.clone(), k, n, kind);
            let mut out = vec![0f32; mm * n];
            lin.forward(&a, mm, &mut out);
            let w_deq = lin.dequantized_weights();
            for i in 0..mm {
                for j in 0..n {
                    let mut acc = 0f64;
                    for kk in 0..k {
                        acc += a_deq[i * k + kk] as f64 * w_deq[kk * n + j] as f64;
                    }
                    let got = out[i * n + j] as f64;
                    assert!(
                        (got - acc).abs() <= 1e-4 * acc.abs().max(1.0),
                        "trial {trial} {kind:?} ({mm}x{k}x{n}) element ({i},{j}): \
                         kernel {got} vs dequantized reference {acc}"
                    );
                }
            }
        }
    }
}

#[test]
fn rotation_composition_is_consistent() {
    // metamorphic: rotating twice equals rotating by the composition —
    // guards the harness itself (a broken rotate() would silence the suite)
    let m = Manifest::reference();
    let mut rng = Rng::new(11);
    let r1 = rng.rotation();
    let r2 = rng.rotation();
    let pos = m.molecule.positions.clone();
    let once = rotate(&rotate(&pos, &r1), &r2);
    // compose: (r2 * r1)
    let mut comp = [[0f64; 3]; 3];
    for (i, row) in comp.iter_mut().enumerate() {
        for (j, cell) in row.iter_mut().enumerate() {
            *cell = (0..3).map(|k| r2[i][k] * r1[k][j]).sum();
        }
    }
    let twice = rotate(&pos, &comp);
    for (a, b) in once.iter().zip(&twice) {
        assert!((a - b).abs() < 1e-12, "{a} vs {b}");
    }
}
