//! Deterministic fault-injection suite (ISSUE 9): drives the failpoint
//! harness through the coordinator, the TCP front-end, and the trajectory
//! store, asserting the failure-model contracts of DESIGN.md §13:
//!
//! * a worker panic mid-batch loses zero requests (Drop guards answer) and
//!   the supervised pool respawns under the capped backoff;
//! * client-side transport failures are *typed*: a deadline expiry is
//!   [`TransportError::Timeout`], a mid-frame tear is `Disconnected`;
//! * a stuck backend surfaces as the server-authoritative `Timeout`
//!   rejection, not a client hang;
//! * an injected short write rolls the segment back to the previous record
//!   boundary — the store reopens clean;
//! * the dispatcher submit failpoint refuses before a request enters the
//!   system (no gauge leak).
//!
//! The failpoint registry is process-global, so every test here serialises
//! on one mutex and clears the registry on entry and exit.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use gaq_md::coordinator::{
    Backend, BatchPolicy, InferenceRequest, InferenceResponse, Metrics, NetClient, NetConfig,
    NetServer, Pool, Server, ServerConfig,
};
use gaq_md::store::checkpoint::MdFrame;
use gaq_md::store::RunStore;
use gaq_md::util::failpoint;
use gaq_md::util::json::Json;

/// Serialise tests that touch the process-global failpoint registry.
fn guard() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("gaq_fault_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn mk_req(id: u64) -> (InferenceRequest, mpsc::Receiver<InferenceResponse>) {
    let (tx, rx) = mpsc::channel();
    (InferenceRequest::new(id, "mock", vec![1.0; 6], tx, None), rx)
}

/// Dispatch one request; if the pool has no live worker this instant,
/// answer through the request's own terminal path (what the dispatcher
/// does) so the accounting stays closed either way.
fn dispatch_one(pool: &Pool, id: u64) -> mpsc::Receiver<InferenceResponse> {
    let (req, rx) = mk_req(id);
    if let Err(batch) = pool.dispatch(vec![req]) {
        for r in batch {
            let id = r.id;
            r.respond(InferenceResponse::error(id, "no live workers"));
        }
    }
    rx
}

fn mock_net_server(backend: Backend, cfg: NetConfig) -> NetServer {
    let server = Server::start(ServerConfig {
        policy: BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_micros(200),
            max_queue_depth: 1024,
        },
        variants: vec![("mock".to_string(), backend, 1)],
    })
    .expect("server starts");
    NetServer::start(server, cfg.with_expected_len(6)).expect("net server binds")
}

/// Satellite 2: kill workers under load via the `pool/worker_batch` panic
/// failpoint. Every request must still be answered (zero lost), the pool
/// must respawn workers, and throughput must be restored once the fault
/// clears.
#[test]
fn worker_panics_lose_zero_requests_and_pool_respawns() {
    let _g = guard();
    failpoint::clear_all();
    let respawns0 = gaq_md::obs::counter("worker_respawns_total").get();
    let trips0 = gaq_md::obs::counter("failpoint_trips_total").get();

    let metrics = Arc::new(Mutex::new(Metrics::default()));
    let pool = Pool::supervised("mock".into(), Backend::Mock { n_atoms: 2 }, 2, metrics)
        .expect("supervised pool starts");

    // sanity: the pool serves before any fault is injected
    let rx = dispatch_one(&pool, 0);
    let r = rx.recv_timeout(Duration::from_secs(10)).expect("baseline reply");
    assert!(r.error.is_none(), "baseline request failed: {:?}", r.error);

    // every batch taken from here on panics its worker mid-batch
    failpoint::set("pool/worker_batch", "panic").unwrap();
    let n = 8u64;
    let rxs: Vec<_> = (1..=n)
        .map(|i| {
            let rx = dispatch_one(&pool, i);
            std::thread::sleep(Duration::from_millis(10));
            rx
        })
        .collect();
    // zero lost: every request gets exactly one reply — from the panicking
    // worker's Drop guards or from the no-live-workers fallback above
    for (i, rx) in rxs.iter().enumerate() {
        let r = rx
            .recv_timeout(Duration::from_secs(10))
            .unwrap_or_else(|e| panic!("request {} lost under worker panics: {e}", i + 1));
        assert!(r.error.is_some(), "a panicked batch cannot produce a success");
    }
    assert!(
        gaq_md::obs::counter("failpoint_trips_total").get() > trips0,
        "panic failpoint never tripped"
    );

    // fault cleared: the supervisor must restore service (respawned worker
    // answers ok), within the capped backoff horizon
    failpoint::clear_all();
    let mut recovered = false;
    for i in 0..400u64 {
        let rx = dispatch_one(&pool, 1000 + i);
        if let Ok(r) = rx.recv_timeout(Duration::from_secs(10)) {
            if r.error.is_none() {
                recovered = true;
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    assert!(recovered, "pool never recovered after the panic fault cleared");
    assert!(
        gaq_md::obs::counter("worker_respawns_total").get() > respawns0,
        "recovery without a recorded respawn"
    );
    pool.shutdown();
}

/// Satellite 1: a reply that misses the client's read deadline is a typed
/// `Timeout`, not a generic error and not a disconnect.
#[test]
fn client_read_deadline_is_typed_timeout() {
    let _g = guard();
    failpoint::clear_all();
    let net = mock_net_server(
        Backend::SlowMock { n_atoms: 2, delay_ms: 500 },
        NetConfig::new("127.0.0.1:0"),
    );
    let mut client = NetClient::connect_with_deadlines(
        &net.local_addr().to_string(),
        Duration::from_millis(100),
        Duration::from_secs(5),
    )
    .expect("client connects");
    client.send_infer(1, "mock", &[1.0; 6]).expect("send");
    let err = client.recv_typed().expect_err("a 500 ms backend beat a 100 ms deadline");
    assert!(err.is_timeout(), "expected Timeout, got {err:?}");
    assert!(!err.is_disconnect(), "{err:?}");
    drop(client);
    net.shutdown();
}

/// Satellite 1 (other half): a connection torn mid-frame by the
/// `net/write_reply` failpoint is a typed `Disconnected` — distinguishable
/// from a timeout — and a fresh connection works once the fault clears.
#[test]
fn mid_frame_disconnect_is_typed_disconnect() {
    let _g = guard();
    failpoint::clear_all();
    let net =
        mock_net_server(Backend::Mock { n_atoms: 2 }, NetConfig::new("127.0.0.1:0"));
    failpoint::set("net/write_reply", "disconnect").unwrap();
    let mut client = NetClient::connect(&net.local_addr().to_string()).expect("connect");
    client.send_infer(3, "mock", &[1.0; 6]).expect("send");
    let err = client.recv_typed().expect_err("server tore the reply mid-frame");
    assert!(err.is_disconnect(), "expected Disconnected, got {err:?}");

    failpoint::clear_all();
    let mut c2 = NetClient::connect(&net.local_addr().to_string()).expect("reconnect");
    let r = c2.infer(4, "mock", &[1.0; 6]).expect("round trip after fault cleared");
    assert!(r.is_ok(), "{r:?}");
    drop((client, c2));
    net.shutdown();
}

/// A backend slower than the server's per-request deadline surfaces as the
/// typed `Timeout` rejection on the server's authority — the client is
/// never left hanging on a wedged worker.
#[test]
fn server_request_deadline_surfaces_timeout_rejection() {
    let _g = guard();
    failpoint::clear_all();
    let net = mock_net_server(
        Backend::SlowMock { n_atoms: 2, delay_ms: 400 },
        NetConfig::new("127.0.0.1:0").with_request_deadline(Duration::from_millis(50)),
    );
    let mut client = NetClient::connect(&net.local_addr().to_string()).expect("connect");
    let r = client.infer(9, "mock", &[1.0; 6]).expect("a reply, not a hang");
    assert_eq!(r.reject_code(), Some("Timeout"), "{r:?}");
    assert_eq!(r.id, Some(9));
    assert!(
        net.stats().timeouts.load(Ordering::Relaxed) >= 1,
        "timeout not counted in NetStats"
    );
    drop(client);
    net.shutdown();
}

/// An injected short write (torn append / ENOSPC) fails the append but
/// rolls the segment back to the previous record boundary: subsequent
/// appends succeed and the store reopens with zero torn bytes.
#[test]
fn store_short_write_rolls_back_to_record_boundary() {
    let _g = guard();
    failpoint::clear_all();
    let frame = |step: u64| MdFrame {
        step,
        time_fs: step as f64 * 0.25,
        pe_ev: -1.5,
        ke_ev: 0.25,
        positions: vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6],
        velocities: vec![0.0; 6],
    };

    let dir = tmpdir("shortwrite");
    let mut store = RunStore::create(&dir, "md", Json::Null).expect("create store");
    store.append_frame(&frame(0)).expect("clean append");

    failpoint::set("store/append", "shortwrite:5").unwrap();
    let err = store.append_frame(&frame(1));
    assert!(err.is_err(), "short write must fail the append");
    failpoint::clear_all();

    // the torn prefix was rolled back: the next append lands cleanly
    store.append_frame(&frame(2)).expect("append after rollback");
    store.finalize().expect("finalize");
    drop(store);

    let (reopened, report) = RunStore::open(&dir, "md", Json::Null).expect("reopen");
    assert_eq!(report.truncated_bytes(), 0, "rollback left a torn tail on disk");
    let steps: Vec<u64> = reopened.frames().unwrap().iter().map(|f| f.step).collect();
    assert_eq!(steps, vec![0, 2], "surviving frames are exactly the completed appends");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The `coordinator/submit` failpoint refuses a request before it enters
/// the system; once cleared, the same server serves normally (the depth
/// gauge was never touched by the refused submit).
#[test]
fn submit_failpoint_refuses_before_admission() {
    let _g = guard();
    failpoint::clear_all();
    let server = Server::start(ServerConfig {
        policy: BatchPolicy::default(),
        variants: vec![("mock".to_string(), Backend::Mock { n_atoms: 2 }, 1)],
    })
    .expect("server starts");

    let p = server.submit("mock", vec![1.0; 6]).expect("baseline submit");
    assert!(p.wait().expect("baseline reply").error.is_none());

    failpoint::set("coordinator/submit", "err").unwrap();
    assert!(
        server.submit("mock", vec![1.0; 6]).is_err(),
        "injected submit failure must refuse the request"
    );
    failpoint::clear_all();

    let p = server.submit("mock", vec![1.0; 6]).expect("submit after fault cleared");
    assert!(p.wait().expect("reply").error.is_none());
}
