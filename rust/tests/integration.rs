//! Integration tests over the real AOT artifacts (runtime + coordinator +
//! MD + LEE). Each test skips with a clear message when `make artifacts`
//! (or `make smoke`) has not run — unit coverage lives in the modules.

use gaq_md::coordinator::{Backend, BatchPolicy, Server, ServerConfig};
use gaq_md::md::integrator::MdState;
use gaq_md::md::{integrator, ClassicalProvider, ForceProvider};
use gaq_md::runtime::{CompiledForceField, Engine, Manifest, ModelForceProvider};
use gaq_md::util::prng::Rng;

fn manifest() -> Option<Manifest> {
    for dir in ["artifacts", "artifacts_smoke"] {
        if std::path::Path::new(dir).join("manifest.json").exists() {
            return Some(Manifest::load(dir).expect("manifest parses"));
        }
    }
    eprintln!("SKIP: no artifacts; run `make artifacts` or `make smoke`");
    None
}

fn artifacts_dir() -> Option<String> {
    for dir in ["artifacts", "artifacts_smoke"] {
        if std::path::Path::new(dir).join("manifest.json").exists() {
            return Some(dir.to_string());
        }
    }
    None
}

#[test]
fn manifest_is_complete() {
    let Some(m) = manifest() else { return };
    assert_eq!(m.molecule.n_atoms(), 24);
    assert!(m.variants.contains_key("fp32"));
    assert!(m.variants.contains_key("gaq_w4a8"));
    for (name, v) in &m.variants {
        assert!(v.hlo.exists(), "{name}: missing {}", v.hlo.display());
        assert!(v.weights_bin.exists(), "{name}: missing weight image");
        assert!(v.weights_bytes > 0);
        for (b, p) in &v.hlo_batched {
            assert!(p.exists(), "{name}: missing batch-{b} artifact");
        }
    }
}

#[test]
fn compiled_model_single_inference() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().expect("pjrt client");
    let v = m.variant("gaq_w4a8").unwrap();
    let ff = CompiledForceField::load(&engine, v, m.molecule.n_atoms()).expect("compile");
    let pos: Vec<f32> = m.molecule.positions.iter().map(|&x| x as f32).collect();
    let (e, f) = ff.energy_forces_f32(&pos).expect("execute");
    assert!(e.is_finite());
    assert_eq!(f.len(), 72);
    assert!(f.iter().all(|x| x.is_finite()), "forces must be finite");
    // force magnitudes physically plausible (< 50 eV/A)
    assert!(f.iter().all(|x| x.abs() < 50.0));
}

#[test]
fn compiled_model_rejects_bad_shape() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let v = m.variant("fp32").unwrap();
    let ff = CompiledForceField::load(&engine, v, m.molecule.n_atoms()).unwrap();
    assert!(ff.energy_forces_f32(&[0.0; 10]).is_err());
}

#[test]
fn batched_matches_single() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let v = m.variant("fp32").unwrap();
    let ff = CompiledForceField::load(&engine, v, m.molecule.n_atoms()).unwrap();
    let base: Vec<f32> = m.molecule.positions.iter().map(|&x| x as f32).collect();
    let mut rng = Rng::new(1);
    let batch: Vec<Vec<f32>> = (0..5)
        .map(|_| base.iter().map(|&x| x + 0.02 * rng.gaussian() as f32).collect())
        .collect();
    let outs = ff.energy_forces_batch(&batch).expect("batched exec");
    assert_eq!(outs.len(), 5);
    for (i, pos) in batch.iter().enumerate() {
        let (e, f) = ff.energy_forces_f32(pos).unwrap();
        assert!(
            (outs[i].0 - e).abs() < 1e-4,
            "batch[{i}] energy {} vs single {e}",
            outs[i].0
        );
        for (a, b) in outs[i].1.iter().zip(&f) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}

#[test]
fn deployed_fp32_lee_is_tiny_and_naive_is_not() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let mut lee = std::collections::BTreeMap::new();
    for name in ["fp32", "naive_int8", "gaq_w4a8"] {
        let Ok(v) = m.variant(name) else { continue };
        let ff = std::sync::Arc::new(
            CompiledForceField::load(&engine, v, m.molecule.n_atoms()).unwrap(),
        );
        let mut p = ModelForceProvider::new(ff);
        let rep = gaq_md::lee::measure_lee(&mut p, &m.molecule.positions, 4, 9).unwrap();
        lee.insert(name, rep.force_lee_mev_a);
    }
    // fp32 is equivariant up to f32 noise; quantized variants are not.
    assert!(lee["fp32"] < 1.0, "fp32 LEE = {}", lee["fp32"]);
    if let (Some(&n8), Some(&g)) = (lee.get("naive_int8"), lee.get("gaq_w4a8")) {
        assert!(g < n8, "GAQ LEE {g} must beat naive {n8}");
    }
}

#[test]
fn server_serves_pjrt_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let server = Server::start(ServerConfig {
        policy: BatchPolicy {
            max_batch: 4,
            max_wait: std::time::Duration::from_micros(300),
        },
        variants: vec![(
            "fp32".into(),
            Backend::Pjrt { artifacts_dir: dir.clone(), variant: "fp32".into() },
            1,
        )],
    })
    .expect("server start");
    let base: Vec<f32> = m.molecule.positions.iter().map(|&x| x as f32).collect();
    let pend: Vec<_> = (0..12).map(|_| server.submit("fp32", base.clone()).unwrap()).collect();
    for p in pend {
        let r = p.wait_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.energy_ev.is_finite());
    }
    let metrics = server.metrics();
    assert_eq!(metrics.completed, 12);
    server.shutdown();
}

#[test]
fn md_runs_with_compiled_forcefield() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let v = m.variant("gaq_w4a8").unwrap();
    let ff = std::sync::Arc::new(
        CompiledForceField::load(&engine, v, m.molecule.n_atoms()).unwrap(),
    );
    let mut provider = ModelForceProvider::new(ff);
    let mut state = MdState::new(m.molecule.positions.clone(), m.molecule.masses.clone());
    let mut rng = Rng::new(2);
    state.thermalize(100.0, &mut rng);
    let (_, mut forces) = provider.energy_forces(&state.positions).unwrap();
    for _ in 0..25 {
        let (pe, f) = integrator::verlet_step(&mut state, &forces, 0.25, &mut provider).unwrap();
        forces = f;
        assert!(pe.is_finite());
    }
    assert!(state.positions.iter().all(|x| x.is_finite()));
}

#[test]
fn classical_and_model_agree_near_equilibrium() {
    // the trained fp32 model should predict forces correlated with the
    // oracle labels it was trained on (sanity of the whole train+AOT path)
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let v = m.variant("fp32").unwrap();
    let ff = CompiledForceField::load(&engine, v, m.molecule.n_atoms()).unwrap();
    let mut cp = ClassicalProvider { ff: m.molecule.ff.clone() };

    let mut rng = Rng::new(3);
    let mut r = m.molecule.positions.clone();
    for x in r.iter_mut() {
        *x += 0.05 * rng.gaussian();
    }
    let (_, f_oracle) = cp.energy_forces(&r).unwrap();
    let rf: Vec<f32> = r.iter().map(|&x| x as f32).collect();
    let (_, f_model) = ff.energy_forces_f32(&rf).unwrap();

    let dot: f64 = f_oracle.iter().zip(&f_model).map(|(a, &b)| a * b as f64).sum();
    let na: f64 = f_oracle.iter().map(|a| a * a).sum::<f64>().sqrt();
    let nb: f64 = f_model.iter().map(|&b| (b as f64) * (b as f64)).sum::<f64>().sqrt();
    let cos = dot / (na * nb + 1e-12);
    // smoke artifacts are barely trained; full artifacts should correlate well
    assert!(cos > 0.15, "model/oracle force cosine = {cos}");
}
