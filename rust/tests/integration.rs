//! Integration tests over the runtime + coordinator + MD + LEE stack.
//!
//! These always run: when AOT artifacts exist (`make artifacts` /
//! `make smoke`) they exercise the on-disk manifest, otherwise the builtin
//! reference manifest served by the pure-Rust backend (runtime/reference.rs).
//! Artifact-file assertions apply only to on-disk manifests; everything else
//! — server, MD integration, LEE ordering — is backend-independent contract.

use gaq_md::coordinator::{Backend, BatchPolicy, Server, ServerConfig};
use gaq_md::md::integrator::MdState;
use gaq_md::md::{integrator, ClassicalProvider, ForceProvider};
use gaq_md::runtime::{self, Manifest, ModelForceProvider};
use gaq_md::util::prng::Rng;

fn artifacts_dir() -> String {
    gaq_md::resolve_artifacts_dir(None)
}

fn manifest() -> Manifest {
    Manifest::load_or_reference(artifacts_dir()).expect("manifest parses")
}

fn load(variant: &str) -> std::sync::Arc<runtime::CompiledForceField> {
    let (_, _engine, ff) = runtime::load_variant(&artifacts_dir(), variant).expect("load variant");
    ff
}

#[test]
fn manifest_is_complete() {
    let m = manifest();
    assert_eq!(m.molecule.n_atoms(), 24);
    assert!(m.variants.contains_key("fp32"));
    assert!(m.variants.contains_key("gaq_w4a8"));
    for (name, v) in &m.variants {
        assert!(v.weights_bytes > 0, "{name}: zero weight image");
        if !m.builtin {
            assert!(v.hlo.exists(), "{name}: missing {}", v.hlo.display());
            assert!(v.weights_bin.exists(), "{name}: missing weight image");
            for (b, p) in &v.hlo_batched {
                assert!(p.exists(), "{name}: missing batch-{b} artifact");
            }
        }
    }
}

#[test]
fn compiled_model_single_inference() {
    let m = manifest();
    let ff = load("gaq_w4a8");
    let pos: Vec<f32> = m.molecule.positions.iter().map(|&x| x as f32).collect();
    let (e, f) = ff.energy_forces_f32(&pos).expect("execute");
    assert!(e.is_finite());
    assert_eq!(f.len(), 72);
    assert!(f.iter().all(|x| x.is_finite()), "forces must be finite");
    // force magnitudes physically plausible (< 50 eV/A)
    assert!(f.iter().all(|x| x.abs() < 50.0));
}

#[test]
fn compiled_model_rejects_bad_shape() {
    let ff = load("fp32");
    assert!(ff.energy_forces_f32(&[0.0; 10]).is_err());
}

#[test]
fn batched_matches_single() {
    let m = manifest();
    let ff = load("fp32");
    let base: Vec<f32> = m.molecule.positions.iter().map(|&x| x as f32).collect();
    let mut rng = Rng::new(1);
    let batch: Vec<Vec<f32>> = (0..5)
        .map(|_| base.iter().map(|&x| x + 0.02 * rng.gaussian() as f32).collect())
        .collect();
    let outs = ff.energy_forces_batch(&batch).expect("batched exec");
    assert_eq!(outs.len(), 5);
    for (i, pos) in batch.iter().enumerate() {
        let (e, f) = ff.energy_forces_f32(pos).unwrap();
        assert!(
            (outs[i].0 - e).abs() < 1e-4,
            "batch[{i}] energy {} vs single {e}",
            outs[i].0
        );
        for (a, b) in outs[i].1.iter().zip(&f) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}

#[test]
fn deployed_fp32_lee_is_tiny_and_naive_is_not() {
    let m = manifest();
    let mut lee = std::collections::BTreeMap::new();
    for name in ["fp32", "naive_int8", "gaq_w4a8"] {
        if m.variant(name).is_err() {
            continue;
        }
        let mut p = ModelForceProvider::new(load(name));
        let rep = gaq_md::lee::measure_lee(&mut p, &m.molecule.positions, 4, 9).unwrap();
        lee.insert(name, rep.force_lee_mev_a);
    }
    // fp32 is equivariant up to f32 noise; quantized variants are not.
    assert!(lee["fp32"] < 1.0, "fp32 LEE = {}", lee["fp32"]);
    if let (Some(&n8), Some(&g)) = (lee.get("naive_int8"), lee.get("gaq_w4a8")) {
        assert!(g < n8, "GAQ LEE {g} must beat naive {n8}");
    }
}

#[test]
fn gaq_preserves_symmetry_that_naive_breaks() {
    // the Table III mechanism on perturbed (off-equilibrium) geometries,
    // where forces are larger and the effect is unambiguous
    let m = manifest();
    let mut rng = Rng::new(4);
    let mut pos = m.molecule.positions.clone();
    for x in pos.iter_mut() {
        *x += 0.05 * rng.gaussian();
    }
    let mut out = std::collections::BTreeMap::new();
    for name in ["naive_int8", "degree_quant", "gaq_w4a8"] {
        if m.variant(name).is_err() {
            continue;
        }
        let mut p = ModelForceProvider::new(load(name));
        let rep = gaq_md::lee::measure_lee(&mut p, &pos, 8, 11).unwrap();
        out.insert(name, rep.force_lee_mev_a);
    }
    if let (Some(&naive), Some(&gaq)) = (out.get("naive_int8"), out.get("gaq_w4a8")) {
        assert!(naive > 0.0 && gaq > 0.0, "quantized variants have nonzero LEE: {out:?}");
        assert!(gaq * 2.0 < naive, "GAQ {gaq} should suppress naive {naive} clearly");
        if let Some(&dq) = out.get("degree_quant") {
            assert!(dq < naive, "degree-quant {dq} partially preserves vs naive {naive}");
        }
    } else {
        eprintln!("note: manifest lacks naive_int8/gaq_w4a8; ordering not asserted");
    }
}

#[test]
fn server_serves_pjrt_backend_requests() {
    // Backend::Pjrt must serve under every build: PJRT executables when the
    // feature + artifacts exist, transparent reference fallback otherwise.
    let dir = artifacts_dir();
    let m = manifest();
    let server = Server::start(ServerConfig {
        policy: BatchPolicy {
            max_batch: 4,
            max_wait: std::time::Duration::from_micros(300),
            ..BatchPolicy::default()
        },
        variants: vec![(
            "fp32".into(),
            Backend::Pjrt { artifacts_dir: dir.clone(), variant: "fp32".into() },
            1,
        )],
    })
    .expect("server start");
    let base: Vec<f32> = m.molecule.positions.iter().map(|&x| x as f32).collect();
    let pend: Vec<_> = (0..12).map(|_| server.submit("fp32", base.clone()).unwrap()).collect();
    for p in pend {
        let r = p.wait_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.energy_ev.is_finite());
    }
    let metrics = server.metrics();
    assert_eq!(metrics.completed, 12);
    server.shutdown();
}

#[test]
fn server_serves_reference_backend_requests() {
    let dir = artifacts_dir();
    let m = manifest();
    let mk = |v: &str| Backend::Reference { artifacts_dir: dir.clone(), variant: v.into() };
    let server = Server::start(ServerConfig {
        policy: BatchPolicy {
            max_batch: 8,
            max_wait: std::time::Duration::from_micros(300),
            ..BatchPolicy::default()
        },
        variants: vec![
            ("fp32".into(), mk("fp32"), 2),
            ("gaq_w4a8".into(), mk("gaq_w4a8"), 2),
        ],
    })
    .expect("server start");
    let base: Vec<f32> = m.molecule.positions.iter().map(|&x| x as f32).collect();
    let mut rng = Rng::new(8);
    let mut pend = Vec::new();
    for i in 0..32 {
        let mut pos = base.clone();
        for p in pos.iter_mut() {
            *p += (0.02 * rng.gaussian()) as f32;
        }
        let v = if i % 2 == 0 { "fp32" } else { "gaq_w4a8" };
        pend.push(server.submit(v, pos).unwrap());
    }
    for p in pend {
        let r = p.wait_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.energy_ev.is_finite());
        assert_eq!(r.forces.len(), base.len());
    }
    assert_eq!(server.metrics().completed, 32);
    server.shutdown();
}

#[test]
fn md_runs_with_compiled_forcefield() {
    let m = manifest();
    let mut provider = ModelForceProvider::new(load("gaq_w4a8"));
    let mut state = MdState::new(m.molecule.positions.clone(), m.molecule.masses.clone());
    let mut rng = Rng::new(2);
    state.thermalize(100.0, &mut rng);
    let (_, mut forces) = provider.energy_forces(&state.positions).unwrap();
    for _ in 0..25 {
        let (pe, f) = integrator::verlet_step(&mut state, &forces, 0.25, &mut provider).unwrap();
        forces = f;
        assert!(pe.is_finite());
    }
    assert!(state.positions.iter().all(|x| x.is_finite()));
}

#[test]
fn nve_with_gaq_variant_conserves_energy_short_horizon() {
    // end-to-end MD stability: the GAQ-quantized force field should not
    // drift pathologically over a short NVE run (the Fig. 3 mechanism)
    let m = manifest();
    let mut provider = ModelForceProvider::new(load("gaq_w4a8"));
    let mut state = MdState::new(m.molecule.positions.clone(), m.molecule.masses.clone());
    let mut rng = Rng::new(6);
    state.thermalize(200.0, &mut rng);
    let (pe0, mut forces) = provider.energy_forces(&state.positions).unwrap();
    let e0 = pe0 + state.kinetic_energy();
    let mut emax: f64 = 0.0;
    for _ in 0..400 {
        let (pe, f) = integrator::verlet_step(&mut state, &forces, 0.25, &mut provider).unwrap();
        forces = f;
        emax = emax.max((pe + state.kinetic_energy() - e0).abs());
    }
    // quantized forces cost some conservation; explosion would be >> 1 eV
    assert!(emax < 0.5, "energy excursion {emax} eV over 400 steps");
}

#[test]
fn classical_and_model_agree_near_equilibrium() {
    // the deployed fp32 model must predict forces correlated with the
    // oracle labels (sanity of the whole load path, any backend)
    let m = manifest();
    let ff = load("fp32");
    let mut cp = ClassicalProvider { ff: m.molecule.ff.clone() };

    let mut rng = Rng::new(3);
    let mut r = m.molecule.positions.clone();
    for x in r.iter_mut() {
        *x += 0.05 * rng.gaussian();
    }
    let (_, f_oracle) = cp.energy_forces(&r).unwrap();
    let rf: Vec<f32> = r.iter().map(|&x| x as f32).collect();
    let (_, f_model) = ff.energy_forces_f32(&rf).unwrap();

    let dot: f64 = f_oracle.iter().zip(&f_model).map(|(a, &b)| a * b as f64).sum();
    let na: f64 = f_oracle.iter().map(|a| a * a).sum::<f64>().sqrt();
    let nb: f64 = f_model.iter().map(|&b| (b as f64) * (b as f64)).sum::<f64>().sqrt();
    let cos = dot / (na * nb + 1e-12);
    // smoke artifacts are barely trained; the reference backend is exact
    assert!(cos > 0.15, "model/oracle force cosine = {cos}");
}
