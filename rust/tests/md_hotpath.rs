//! ISSUE 10 acceptance: steady-state MD steps on the GNN backend perform
//! ZERO heap allocations. A counting global allocator wraps the system
//! allocator; after a warmup phase (buffer high-water marks, span-stack
//! capacity, at least one skin-list rebuild) the allocation counter must
//! not move across 50 production `verlet_step_into` steps.
//!
//! This file intentionally holds a single #[test]: the global allocator is
//! process-wide, and a concurrently running sibling test would perturb the
//! counter. See DESIGN.md §14 for the hot-path memory model this pins down.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use gaq_md::md::integrator::{verlet_step_into, MdState};
use gaq_md::md::ForceProvider;
use gaq_md::runtime::{load_variant_choice, BackendChoice, ModelForceProvider};
use gaq_md::util::prng::Rng;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_gnn_md_steps_do_not_allocate() {
    // Serial GEMM path: the worker pool would allocate per dispatch (task
    // boxing, channel nodes), which is out of scope for the single-replica
    // hot path this test pins down. The pool itself is exercised for
    // bit-parity in tests/parallel_parity.rs.
    std::env::set_var("GAQ_THREADS", "1");

    let (manifest, _engine, ff) =
        load_variant_choice("/nonexistent/nowhere", "gaq_w4a8", BackendChoice::Gnn).unwrap();
    let mol = &manifest.molecule;
    let mut provider = ModelForceProvider::new(ff);

    let mut state = MdState::new(mol.positions.clone(), mol.masses.clone());
    let mut rng = Rng::new(17);
    state.thermalize(300.0, &mut rng);

    let n3 = mol.positions.len();
    let mut forces = vec![0.0f64; n3];
    provider.energy_forces_into(&state.positions, &mut forces).unwrap();

    // Warmup: scratch buffers reach their high-water sizes, the span
    // thread-local stack reaches full nesting depth, interned span names
    // are created, and the skin list rebuilds at least once as atoms
    // drift. 100 steps at 0.5 fs is far past all of those.
    for _ in 0..100 {
        verlet_step_into(&mut state, &mut forces, 0.5, &mut provider).unwrap();
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..50 {
        let pe = verlet_step_into(&mut state, &mut forces, 0.5, &mut provider).unwrap();
        assert!(pe.is_finite());
    }
    let delta = ALLOCS.load(Ordering::SeqCst) - before;

    assert_eq!(
        delta, 0,
        "steady-state MD steps allocated {delta} time(s); the GNN hot path \
         must be zero-alloc (DESIGN.md §14)"
    );
}
