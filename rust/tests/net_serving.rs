//! End-to-end tests for the TCP serving front-end (ISSUE 7 tentpole):
//! real sockets on loopback, length-prefixed JSON frames, the typed
//! rejection taxonomy, admission control, and graceful drain.
//!
//! Loopback only — safe under the CI `GAQ_THREADS` matrix.

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use gaq_md::coordinator::loadgen::{run_net_load, Arrival, NetLoadConfig};
use gaq_md::coordinator::{
    Backend, BatchPolicy, NetClient, NetConfig, NetOutcome, NetServer, Server, ServerConfig,
};
use gaq_md::runtime::Manifest;

/// One-variant mock server on a free loopback port (n_atoms=2 => len 6).
fn mock_net_server(max_batch: usize, max_queue_depth: usize, backend: Backend) -> NetServer {
    let server = Server::start(ServerConfig {
        policy: BatchPolicy {
            max_batch,
            max_wait: Duration::from_micros(200),
            max_queue_depth,
        },
        variants: vec![("mock".to_string(), backend, 1)],
    })
    .expect("server starts");
    NetServer::start(server, NetConfig::new("127.0.0.1:0").with_expected_len(6))
        .expect("net server binds")
}

fn connect(net: &NetServer) -> NetClient {
    NetClient::connect(&net.local_addr().to_string()).expect("client connects")
}

#[test]
fn tcp_round_trip_all_builtin_variants() {
    let m = Manifest::reference();
    let base: Vec<f32> = m.molecule.positions.iter().map(|&x| x as f32).collect();
    let mk = |v: &str| Backend::Reference {
        artifacts_dir: "/nonexistent/nowhere".into(),
        variant: v.into(),
    };
    let roster: Vec<String> = m.variants.keys().cloned().collect();
    let server = Server::start(ServerConfig {
        policy: BatchPolicy::default(),
        variants: roster.iter().map(|v| (v.clone(), mk(v), 1)).collect(),
    })
    .expect("server starts");
    let net = NetServer::start(
        server,
        NetConfig::new("127.0.0.1:0").with_expected_len(base.len()),
    )
    .expect("net server binds");

    let mut client = connect(&net);
    for (i, v) in roster.iter().enumerate() {
        let reply = client.infer(i as u64, v, &base).expect("round trip");
        assert_eq!(reply.id, Some(i as u64), "{v}: id echo");
        match reply.outcome {
            NetOutcome::Ok { energy_ev, ref forces, .. } => {
                assert!(energy_ev.is_finite(), "{v}: energy finite");
                assert_eq!(forces.len(), base.len(), "{v}: forces shape");
            }
            ref other => panic!("{v}: expected ok, got {other:?}"),
        }
    }

    // metrics frame: coordinator counters + front-end counters + registry
    let reply = client.metrics().expect("metrics round trip");
    match reply.outcome {
        NetOutcome::Metrics { metrics, net: netj, registry } => {
            let completed = metrics.get("completed").and_then(|v| v.as_u64()).unwrap();
            assert!(completed >= roster.len() as u64, "completed={completed}");
            let accepted = netj.get("accepted").and_then(|v| v.as_u64()).unwrap();
            assert!(accepted >= roster.len() as u64, "accepted={accepted}");
            // per-variant per-stage histograms are populated after traffic
            let hists = registry.get("histograms").expect("registry histograms");
            for v in &roster {
                for stage in ["coordinator_queue_us", "coordinator_inference_us"] {
                    let name = format!("{stage}{{variant=\"{v}\"}}");
                    let count = hists
                        .get(&name)
                        .and_then(|h| h.get("count"))
                        .and_then(|c| c.as_u64())
                        .unwrap_or(0);
                    assert!(count > 0, "{name} empty after traffic");
                }
            }
        }
        other => panic!("expected metrics, got {other:?}"),
    }

    // metrics_prometheus frame: text exposition of the same registry
    let reply = client.metrics_prometheus().expect("prometheus round trip");
    match reply.outcome {
        NetOutcome::Prometheus { text } => {
            assert!(text.contains("# TYPE gaq_coordinator_queue_us summary"), "{text}");
            assert!(text.contains("gaq_coordinator_inference_us_count"), "{text}");
        }
        other => panic!("expected prometheus, got {other:?}"),
    }
    drop(client);
    net.shutdown();
}

#[test]
fn malformed_unknown_and_bad_shape_rejections() {
    let net = mock_net_server(8, 1024, Backend::Mock { n_atoms: 2 });
    let mut client = connect(&net);

    // well-framed garbage JSON: MalformedFrame, connection stays usable
    client.send_payload(b"{this is not json").expect("send");
    let r = client.recv().expect("reply");
    assert_eq!(r.reject_code(), Some("MalformedFrame"), "{r:?}");

    // well-framed invalid UTF-8: MalformedFrame, connection stays usable
    client.send_payload(&[0xff, 0xfe, 0x00]).expect("send");
    let r = client.recv().expect("reply");
    assert_eq!(r.reject_code(), Some("MalformedFrame"), "{r:?}");

    // unknown request type
    client.send_payload(br#"{"type":"dance","id":5}"#).expect("send");
    let r = client.recv().expect("reply");
    assert_eq!(r.reject_code(), Some("MalformedFrame"), "{r:?}");
    assert_eq!(r.id, Some(5));

    // unknown variant
    let r = client.infer(7, "no_such_variant", &[0.0; 6]).expect("reply");
    assert_eq!(r.reject_code(), Some("UnknownVariant"), "{r:?}");
    assert_eq!(r.id, Some(7));

    // wrong positions length
    let r = client.infer(8, "mock", &[0.0; 9]).expect("reply");
    assert_eq!(r.reject_code(), Some("BadShape"), "{r:?}");

    // ...and the connection still serves real work after all that
    let r = client.infer(9, "mock", &[1.0; 6]).expect("reply");
    assert!(r.is_ok(), "{r:?}");

    // oversized length prefix: one MalformedFrame reply, then the server
    // closes the (unsynchronizable) connection
    client.send_raw(&u32::MAX.to_be_bytes()).expect("send");
    let r = client.recv().expect("reply before close");
    assert_eq!(r.reject_code(), Some("MalformedFrame"), "{r:?}");
    assert!(client.recv().is_err(), "connection should be closed");

    // a fresh connection works
    let mut c2 = connect(&net);
    let r = c2.infer(0, "mock", &[1.0; 6]).expect("reply");
    assert!(r.is_ok(), "{r:?}");
    drop((client, c2));
    net.shutdown();
}

#[test]
fn overload_rejects_with_typed_overloaded() {
    // slow single worker, batch=1, depth bound 2: a pipelined burst of 16
    // must see typed Overloaded rejections, and every admitted request
    // must still be answered ok
    let net = mock_net_server(1, 2, Backend::SlowMock { n_atoms: 2, delay_ms: 30 });
    let mut client = connect(&net);
    let n = 16u64;
    for i in 0..n {
        client.send_infer(i, "mock", &[1.0; 6]).expect("send");
    }
    let mut ok = 0u64;
    let mut overloaded = 0u64;
    for i in 0..n {
        let r = client.recv().expect("no bare disconnect while server is alive");
        assert_eq!(r.id, Some(i), "replies in request order");
        match r.reject_code() {
            None => ok += 1,
            Some("Overloaded") => overloaded += 1,
            Some(other) => panic!("unexpected rejection {other}: {r:?}"),
        }
    }
    assert_eq!(ok + overloaded, n);
    assert!(overloaded > 0, "burst of {n} at depth 2 never rejected");
    assert!(ok > 0, "admission rejected everything");
    drop(client);
    net.shutdown();
}

#[test]
fn shutdown_drains_in_flight_requests() {
    let net = mock_net_server(1, 1024, Backend::SlowMock { n_atoms: 2, delay_ms: 40 });
    let addr = net.local_addr().to_string();
    let k = 4u64;
    let client = std::thread::spawn(move || {
        let mut c = NetClient::connect(&addr).expect("connect");
        for i in 0..k {
            c.send_infer(i, "mock", &[1.0; 6]).expect("send");
        }
        // all k are admitted and in flight when the server drains; each
        // must still get its real answer, not a disconnect
        let mut replies = Vec::new();
        for _ in 0..k {
            replies.push(c.recv().expect("drained reply"));
        }
        replies
    });

    // wait until all k are admitted, then drain while they're in flight
    let t0 = Instant::now();
    while net.stats().accepted.load(Ordering::Relaxed) < k {
        assert!(t0.elapsed() < Duration::from_secs(30), "requests never admitted");
        std::thread::sleep(Duration::from_millis(2));
    }
    net.shutdown();

    let replies = client.join().expect("client thread");
    assert_eq!(replies.len(), k as usize);
    for (i, r) in replies.iter().enumerate() {
        assert!(r.is_ok(), "in-flight request {i} not drained: {r:?}");
    }
}

#[test]
fn zero_lost_requests_under_network_load() {
    let net = mock_net_server(8, 1024, Backend::Mock { n_atoms: 2 });
    let mut cfg = NetLoadConfig::new(
        net.local_addr().to_string(),
        vec!["mock".to_string()],
        vec![1.0; 6],
    );
    cfg.n_requests = 200;
    cfg.clients = 4;
    cfg.window = 16;
    cfg.arrival = Arrival::Poisson { rate: 5000.0 };
    let stats = run_net_load(&cfg);
    assert_eq!(stats.sent, 200, "{stats:?}");
    assert_eq!(stats.transport_errors, 0, "{stats:?}");
    assert_eq!(stats.completed + stats.rejected, 200, "{stats:?}");
    // depth bound 1024 is never hit by 4x50 pipelined at window 16
    assert_eq!(stats.completed, 200, "{stats:?}");
    net.shutdown();
}
