//! Observability integration tests (ISSUE 8): histogram error bounds on
//! randomized samples, snapshot merge algebra, span nesting across the
//! threadpool, and ring-wrap integrity.
//!
//! The span tests share one process-global trace ring, so they serialize on
//! [`TRACE_LOCK`] and pin the capacity with the first `enable_tracing` call.

use std::sync::Mutex;

use gaq_md::obs::hist::{HistSnapshot, LogHistogram, SUB};
use gaq_md::obs::span::{self, SpanGuard};
use gaq_md::quant::gemm::{gemm_f32, gemm_f32_pool};
use gaq_md::util::prng::Rng;
use gaq_md::util::threadpool::ThreadPool;

/// Small ring so the wrap test is cheap; both span tests request the same
/// capacity (first call wins) and hold this lock while touching the ring.
const RING_CAP: usize = 1024;
static TRACE_LOCK: Mutex<()> = Mutex::new(());

/// Exact percentile with the same rank rule as `HistSnapshot::percentile`.
fn exact_percentile(sorted: &[u64], p: f64) -> u64 {
    let rank = ((sorted.len() as f64 * p) as usize).min(sorted.len() - 1);
    sorted[rank]
}

#[test]
fn histogram_percentiles_track_exact_within_error_bound() {
    // Mixed-magnitude samples: uniform exponent in [0, 40), uniform mantissa.
    for seed in [3u64, 17, 99] {
        let mut rng = Rng::new(seed);
        let h = LogHistogram::new();
        let mut vals: Vec<u64> = (0..10_000)
            .map(|_| {
                let shift = rng.below(40) as u32;
                let v = (rng.f64() * (1u64 << shift) as f64) as u64;
                h.record(v);
                v
            })
            .collect();
        vals.sort_unstable();
        let s = h.snapshot();
        for p in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 0.999] {
            let exact = exact_percentile(&vals, p);
            let approx = s.percentile(p).expect("nonempty");
            if exact < SUB as u64 {
                // linear region: buckets are exact
                assert_eq!(approx, exact, "seed {seed} p {p}");
            } else {
                let err = (approx as f64 - exact as f64).abs() / exact as f64;
                assert!(
                    err <= 1.0 / 32.0 + 1e-9,
                    "seed {seed} p {p}: exact {exact} approx {approx} err {err}"
                );
            }
        }
        // exact moments regardless of bucketing
        assert_eq!(s.count, vals.len() as u64);
        assert_eq!(s.sum, vals.iter().sum::<u64>());
        assert_eq!(s.max, *vals.last().unwrap());
    }
}

#[test]
fn snapshot_merge_is_associative_and_commutative() {
    let mut rng = Rng::new(42);
    let mut parts: Vec<HistSnapshot> = Vec::new();
    for _ in 0..3 {
        let mut s = HistSnapshot::new();
        for _ in 0..500 {
            let shift = rng.below(30) as u32;
            s.record((rng.f64() * (1u64 << shift) as f64) as u64);
        }
        parts.push(s);
    }
    let (a, b, c) = (&parts[0], &parts[1], &parts[2]);

    let mut ab_c = a.clone();
    ab_c.merge(b);
    ab_c.merge(c);

    let mut bc = b.clone();
    bc.merge(c);
    let mut a_bc = a.clone();
    a_bc.merge(&bc);

    assert_eq!(ab_c, a_bc, "(a+b)+c != a+(b+c)");

    let mut ba = b.clone();
    ba.merge(a);
    let mut ab = a.clone();
    ab.merge(b);
    assert_eq!(ab, ba, "a+b != b+a");
    assert_eq!(ab_c.count, a.count + b.count + c.count);
}

#[test]
fn pool_worker_spans_nest_under_their_region() {
    let _guard = TRACE_LOCK.lock().unwrap();
    gaq_md::obs::enable_tracing(RING_CAP);

    let pool = ThreadPool::new(4);
    // enough tasks that the pool actually forks (workers > 1)
    pool.for_each(64, |_| std::hint::black_box(()));

    let events = span::snapshot_events();
    let region_ids: Vec<u64> = events
        .iter()
        .filter(|e| e.name() == "pool_region")
        .map(|e| e.id)
        .collect();
    assert!(!region_ids.is_empty(), "no pool_region span recorded");
    let workers: Vec<_> =
        events.iter().filter(|e| e.name() == "pool_worker").collect();
    assert!(!workers.is_empty(), "no pool_worker spans recorded");
    // every worker span links to a recorded region despite running on a
    // different OS thread than the one that opened the region
    for w in &workers {
        assert!(
            region_ids.contains(&w.parent),
            "worker span {} has parent {} not in {region_ids:?}",
            w.id,
            w.parent
        );
    }
}

/// Acceptance (ISSUE 8): instrumentation must not perturb the bit-identical
/// serial/pooled contract — verified with tracing actually enabled, so the
/// span/ring machinery is live on both legs.
#[test]
fn pooled_matches_serial_bitwise_with_tracing_enabled() {
    let _guard = TRACE_LOCK.lock().unwrap();
    gaq_md::obs::enable_tracing(RING_CAP);
    let mut rng = Rng::new(7);
    let (m, k, n) = (64usize, 32usize, 48usize);
    let a: Vec<f32> = (0..m * k).map(|_| rng.f32() - 0.5).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.f32() - 0.5).collect();
    let mut c_serial = vec![0f32; m * n];
    let mut c_pool = vec![0f32; m * n];
    gemm_f32(&a, &b, &mut c_serial, m, k, n);
    gemm_f32_pool(&ThreadPool::new(4), &a, &b, &mut c_pool, m, k, n);
    assert!(
        c_serial.iter().zip(&c_pool).all(|(x, y)| x.to_bits() == y.to_bits()),
        "pooled GEMM diverged from serial with tracing on"
    );
}

#[test]
fn ring_wraps_without_tearing() {
    let _guard = TRACE_LOCK.lock().unwrap();
    gaq_md::obs::enable_tracing(RING_CAP);
    let ring = span::ring().expect("ring allocated");
    let cap = ring.capacity() as u64;
    let pushed0 = ring.pushed();

    // concurrent writers pushing several times the capacity
    let name = span::intern("obs_wrap_test");
    std::thread::scope(|s| {
        for _ in 0..4 {
            s.spawn(|| {
                for _ in 0..RING_CAP {
                    let _sp = SpanGuard::enter(name);
                }
            });
        }
    });

    assert!(
        ring.pushed() - pushed0 >= 4 * cap,
        "expected >= {} pushes, got {}",
        4 * cap,
        ring.pushed() - pushed0
    );
    let events = span::snapshot_events();
    assert!(events.len() <= ring.capacity(), "snapshot exceeds capacity");
    assert!(!events.is_empty());
    // integrity: unique live span ids, resolvable names, sane clocks —
    // a torn slot would mix fields from two different events
    let mut ids: Vec<u64> = events.iter().map(|e| e.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), events.len(), "duplicate span ids => torn slot");
    for e in &events {
        assert_ne!(e.name(), "?", "unresolvable interned name");
        assert_ne!(e.id, 0);
    }
}
