//! Determinism and parity of the data-parallel layer (DESIGN.md §8):
//! pooled kernels must be **bit-identical** to serial across randomized
//! shapes (including the unaligned-nibble edge rows of `unpack_row`), and
//! MD trajectories must be reproducible for a fixed seed regardless of the
//! pool size (i.e. regardless of `GAQ_THREADS`).

use gaq_md::md::classical;
use gaq_md::md::integrator::{self, MdState};
use gaq_md::md::ForceProvider;
use gaq_md::molecule::ForceField;
use gaq_md::quant::gemm::{
    f32_bits_eq, gemm_f32, gemm_f32_pool, gemm_i8, gemm_i8_pool, gemm_i8_scalar, gemm_packed,
    gemm_packed_pool, gemm_w4a8, gemm_w4a8_pool, gemm_w4a8_scalar, TILE_MR,
};
use gaq_md::quant::pack::{quantize_i4, quantize_i8, PackedB, PANEL_NR};
use gaq_md::quant::simd::{active_kernel, available_kernels, tile_scalar, tile_with};
use gaq_md::util::error::Result;
use gaq_md::util::prng::Rng;
use gaq_md::util::proptest::check;
use gaq_md::util::threadpool::ThreadPool;

fn random_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect()
}

#[test]
fn prop_pooled_gemms_bit_identical_on_randomized_shapes() {
    check(
        "pooled gemm == serial gemm (bitwise)",
        90,
        60,
        |r: &mut Rng| {
            // odd n (and odd k*n products) exercise unpack_row's unaligned
            // leading/trailing nibble branches
            let m = 1 + r.below(24);
            let k = 1 + r.below(48);
            let n = 1 + r.below(33);
            (m, k, n, r.next_u64())
        },
        |&(m, k, n, seed)| {
            let mut rng = Rng::new(seed);
            let a = random_vec(&mut rng, m * k);
            let b = random_vec(&mut rng, k * n);
            let qa = quantize_i8(&a);
            let qb8 = quantize_i8(&b);
            let qb4 = quantize_i4(&b);

            let mut c_serial = vec![0f32; m * n];
            let mut c_pool = vec![0f32; m * n];
            for threads in [2usize, 5] {
                let pool = ThreadPool::new(threads);

                gemm_f32(&a, &b, &mut c_serial, m, k, n);
                gemm_f32_pool(&pool, &a, &b, &mut c_pool, m, k, n);
                if let Err(e) = f32_bits_eq(&c_serial, &c_pool) {
                    return Err(format!("f32 diverged at ({m},{k},{n}) threads={threads}: {e}"));
                }

                gemm_i8(&qa, &qb8, &mut c_serial, m, k, n);
                gemm_i8_pool(&pool, &qa, &qb8, &mut c_pool, m, k, n);
                if let Err(e) = f32_bits_eq(&c_serial, &c_pool) {
                    return Err(format!("i8 diverged at ({m},{k},{n}) threads={threads}: {e}"));
                }

                gemm_w4a8(&qa, &qb4, &mut c_serial, m, k, n);
                gemm_w4a8_pool(&pool, &qa, &qb4, &mut c_pool, m, k, n);
                if let Err(e) = f32_bits_eq(&c_serial, &c_pool) {
                    return Err(format!("w4a8 diverged at ({m},{k},{n}) threads={threads}: {e}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tiled_kernels_bit_identical_to_scalar_oracles_on_randomized_shapes() {
    // the register-tiled packed kernels (DESIGN.md §10) against the
    // pre-refactor scalar triple loops: odd M (row-tail of the MR tile),
    // K not a multiple of anything in particular, and N straddling the
    // panel width so the natural-width tail panel is exercised; odd k*n
    // additionally lands W4 rows on unaligned nibbles
    check(
        "tiled gemm == scalar oracle (bitwise)",
        91,
        60,
        |r: &mut Rng| {
            let m = 1 + r.below(21);
            let k = 1 + r.below(50);
            let n = 1 + r.below(2 * PANEL_NR + 5);
            (m, k, n, r.next_u64())
        },
        |&(m, k, n, seed)| {
            let mut rng = Rng::new(seed);
            let a = random_vec(&mut rng, m * k);
            let b = random_vec(&mut rng, k * n);
            let qa = quantize_i8(&a);
            let qb8 = quantize_i8(&b);
            let qb4 = quantize_i4(&b);

            let mut c_tiled = vec![0f32; m * n];
            let mut c_scalar = vec![0f32; m * n];

            gemm_i8(&qa, &qb8, &mut c_tiled, m, k, n);
            gemm_i8_scalar(&qa, &qb8, &mut c_scalar, m, k, n);
            if let Err(e) = f32_bits_eq(&c_tiled, &c_scalar) {
                return Err(format!("i8 tiled != scalar at ({m},{k},{n}): {e}"));
            }

            gemm_w4a8(&qa, &qb4, &mut c_tiled, m, k, n);
            gemm_w4a8_scalar(&qa, &qb4, &mut c_scalar, m, k, n);
            if let Err(e) = f32_bits_eq(&c_tiled, &c_scalar) {
                return Err(format!("w4a8 tiled != scalar at ({m},{k},{n}): {e}"));
            }

            // pre-packed images through the same core, each against the
            // scalar oracle of its own quantized image
            gemm_packed(&qa, &PackedB::from_i8(&qb8, k, n), &mut c_tiled, m, k, n);
            gemm_i8_scalar(&qa, &qb8, &mut c_scalar, m, k, n);
            if let Err(e) = f32_bits_eq(&c_tiled, &c_scalar) {
                return Err(format!("packed-i8 != scalar at ({m},{k},{n}): {e}"));
            }
            gemm_packed(&qa, &PackedB::from_i4(&qb4, k, n), &mut c_tiled, m, k, n);
            gemm_w4a8_scalar(&qa, &qb4, &mut c_scalar, m, k, n);
            if let Err(e) = f32_bits_eq(&c_tiled, &c_scalar) {
                return Err(format!("packed-i4 != scalar at ({m},{k},{n}): {e}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_packed_pool_bit_identical_to_serial_on_randomized_shapes() {
    // serial/pooled contract of the tiled path: sharding distributes whole
    // output rows, so pooled output must equal serial bit for bit at every
    // thread count and shape
    check(
        "pooled packed gemm == serial (bitwise)",
        92,
        40,
        |r: &mut Rng| {
            let m = 1 + r.below(24);
            let k = 1 + r.below(40);
            let n = 1 + r.below(2 * PANEL_NR + 3);
            (m, k, n, r.next_u64())
        },
        |&(m, k, n, seed)| {
            let mut rng = Rng::new(seed);
            let a = random_vec(&mut rng, m * k);
            let b = random_vec(&mut rng, k * n);
            let qa = quantize_i8(&a);
            let packed = PackedB::from_i4(&quantize_i4(&b), k, n);

            let mut c_serial = vec![0f32; m * n];
            let mut c_pool = vec![0f32; m * n];
            gemm_packed(&qa, &packed, &mut c_serial, m, k, n);
            for threads in [2usize, 3, 7] {
                let pool = ThreadPool::new(threads);
                gemm_packed_pool(&pool, &qa, &packed, &mut c_pool, m, k, n);
                if let Err(e) = f32_bits_eq(&c_serial, &c_pool) {
                    return Err(format!("packed diverged at ({m},{k},{n}) threads={threads}: {e}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simd_tile_kernels_bit_identical_to_scalar_tile() {
    // every SIMD micro-kernel reachable on this machine against the scalar
    // tile oracle over randomized K extents and full ±127 operand range —
    // run here (not only in the unit tests) so the `GAQ_SIMD={auto,off}`
    // CI matrix exercises the kernels alongside the pooled-parity suite
    check(
        "simd tile kernels == scalar tile (bitwise)",
        93,
        40,
        |r: &mut Rng| (1 + r.below(130), r.next_u64()),
        |&(k, seed)| {
            let mut rng = Rng::new(seed);
            let rows: Vec<Vec<i8>> = (0..TILE_MR)
                .map(|_| (0..k).map(|_| (rng.below(255) as i64 - 127) as i8).collect())
                .collect();
            let panel: Vec<i8> =
                (0..k * PANEL_NR).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
            let a = [&rows[0][..], &rows[1][..], &rows[2][..], &rows[3][..]];
            let mut want = [[0i32; PANEL_NR]; TILE_MR];
            tile_scalar(a, &panel, &mut want);
            for name in available_kernels() {
                let mut got = [[0i32; PANEL_NR]; TILE_MR];
                if !tile_with(name, a, &panel, &mut got) {
                    return Err(format!("kernel {name} listed as available but refused"));
                }
                if got != want {
                    return Err(format!("kernel {name} != scalar tile at k={k}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn dispatched_gemm_matches_scalar_oracle_and_pool_under_gaq_simd() {
    // the full dispatched path (whatever GAQ_SIMD selected for this
    // process) against the scalar triple-loop oracle and the pooled
    // shards, both nibble parities — the CI matrix runs this test twice,
    // once with SIMD auto-detected and once forced off
    let kernel = active_kernel();
    assert!(available_kernels().contains(&kernel), "dispatch picked unknown kernel {kernel}");
    let mut rng = Rng::new(2024);
    for (m, k, n) in [(5usize, 33usize, PANEL_NR + 3), (8, 64, 2 * PANEL_NR), (3, 17, 7)] {
        let a = random_vec(&mut rng, m * k);
        let b = random_vec(&mut rng, k * n);
        let qa = quantize_i8(&a);
        let qb8 = quantize_i8(&b);
        let qb4 = quantize_i4(&b);
        let mut c_simd = vec![0f32; m * n];
        let mut c_scalar = vec![0f32; m * n];
        let mut c_pool = vec![0f32; m * n];

        gemm_packed(&qa, &PackedB::from_i8(&qb8, k, n), &mut c_simd, m, k, n);
        gemm_i8_scalar(&qa, &qb8, &mut c_scalar, m, k, n);
        f32_bits_eq(&c_simd, &c_scalar)
            .unwrap_or_else(|e| panic!("[{kernel}] i8 dispatch != scalar at ({m},{k},{n}): {e}"));

        let packed4 = PackedB::from_i4(&qb4, k, n);
        gemm_packed(&qa, &packed4, &mut c_simd, m, k, n);
        gemm_w4a8_scalar(&qa, &qb4, &mut c_scalar, m, k, n);
        f32_bits_eq(&c_simd, &c_scalar)
            .unwrap_or_else(|e| panic!("[{kernel}] w4a8 dispatch != scalar at ({m},{k},{n}): {e}"));

        for threads in [2usize, 5] {
            gemm_packed_pool(&ThreadPool::new(threads), &qa, &packed4, &mut c_pool, m, k, n);
            f32_bits_eq(&c_simd, &c_pool).unwrap_or_else(|e| {
                panic!("[{kernel}] pooled != serial at ({m},{k},{n}) threads={threads}: {e}")
            });
        }
    }
}

#[test]
fn w4a8_odd_shapes_hit_unaligned_nibble_rows() {
    // deterministic pin of the unpack_row edge cases: odd n makes every
    // other packed weight row start on a high nibble (base = kk*n odd)
    let mut rng = Rng::new(17);
    for (m, k, n) in [(3usize, 7usize, 5usize), (4, 9, 1), (2, 5, 13), (6, 3, 31)] {
        let a = random_vec(&mut rng, m * k);
        let b = random_vec(&mut rng, k * n);
        let qa = quantize_i8(&a);
        let qb4 = quantize_i4(&b);
        let mut c_serial = vec![0f32; m * n];
        let mut c_pool = vec![0f32; m * n];
        gemm_w4a8(&qa, &qb4, &mut c_serial, m, k, n);
        for threads in [2usize, 3, 8] {
            gemm_w4a8_pool(&ThreadPool::new(threads), &qa, &qb4, &mut c_pool, m, k, n);
            if let Err(e) = f32_bits_eq(&c_serial, &c_pool) {
                panic!("w4a8 diverged at ({m},{k},{n}) threads={threads}: {e}");
            }
        }
    }
}

#[test]
fn classical_forces_bit_identical_across_pool_sizes() {
    // all-pairs LJ lattice: 125 atoms -> 7750 pairs, past the threshold
    let (ff, r) = classical::synthetic_lj(5, 23);
    assert!(ff.nb_pairs.len() >= 2048, "system must cross the shard threshold");
    let (e1, f1) = classical::energy_forces_with(&ff, &r, &ThreadPool::new(1));
    for threads in [2usize, 4, 7] {
        let (e2, f2) = classical::energy_forces_with(&ff, &r, &ThreadPool::new(threads));
        assert_eq!(e1.to_bits(), e2.to_bits(), "energy diverged at threads={threads}");
        for (i, (a, b)) in f1.iter().zip(&f2).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "force[{i}] diverged at threads={threads}");
        }
    }
}

/// The classical oracle with an explicit pool — stands in for "the same
/// binary run under a different GAQ_THREADS".
struct PooledClassical {
    ff: ForceField,
    pool: ThreadPool,
}

impl ForceProvider for PooledClassical {
    fn energy_forces(&mut self, positions: &[f64]) -> Result<(f64, Vec<f64>)> {
        Ok(classical::energy_forces_with(&self.ff, positions, &self.pool))
    }
}

#[test]
fn md_trajectory_reproducible_for_any_pool_size() {
    let run = |threads: usize| -> (Vec<f64>, Vec<f64>) {
        let (ff, pos) = classical::synthetic_lj(5, 31);
        let n = pos.len() / 3;
        let mut provider = PooledClassical { ff, pool: ThreadPool::new(threads) };
        let mut state = MdState::new(pos, vec![12.0; n]);
        let mut rng = Rng::new(99);
        state.thermalize(50.0, &mut rng);
        let (_, mut forces) = provider.energy_forces(&state.positions).unwrap();
        for _ in 0..40 {
            let (_, f) = integrator::verlet_step(&mut state, &forces, 0.2, &mut provider).unwrap();
            forces = f;
        }
        (state.positions.clone(), state.velocities.clone())
    };
    let (p1, v1) = run(1);
    for threads in [2usize, 6] {
        let (p2, v2) = run(threads);
        for (i, (a, b)) in p1.iter().zip(&p2).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "position[{i}] diverged at threads={threads}");
        }
        for (i, (a, b)) in v1.iter().zip(&v2).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "velocity[{i}] diverged at threads={threads}");
        }
    }
}
