//! Resume-determinism suite (ISSUE 9, satellite 4): a run killed
//! mid-production and resumed from its store must be *bit-identical* to an
//! uninterrupted run — positions, velocities, energies (frame bytes carry
//! raw f64 bits for all three) and the drift-report fit.
//!
//! The kill is the `md/step` failpoint in err mode: it aborts the run at
//! the top of a chosen production step, exactly where `exit` mode would
//! have killed the process (the store is left unfinalized, with unsynced
//! appends past the last checkpoint — the worst in-process-observable
//! crash state). The `exit`-mode/SIGKILL variant of the same contract is
//! exercised end-to-end by `make store-smoke`.
//!
//! CI runs this suite under both legs of the `GAQ_THREADS` matrix ({1, 0}),
//! so resume determinism is asserted on the serial and parallel force
//! paths alike.
//!
//! The failpoint registry is process-global: tests serialise on one mutex.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard};

use gaq_md::md::runner::{run_md, MdRunConfig, MdRunOutcome};
use gaq_md::md::ClassicalProvider;
use gaq_md::molecule::Molecule;
use gaq_md::store::RunStore;
use gaq_md::util::failpoint;
use gaq_md::util::json::Json;

fn guard() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("gaq_resume_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn provider() -> ClassicalProvider {
    let m = Molecule::azobenzene_builtin();
    ClassicalProvider { ff: m.ff.clone() }
}

fn geometry() -> (Vec<f64>, Vec<f64>) {
    let m = Molecule::azobenzene_builtin();
    (m.positions.clone(), m.masses.clone())
}

fn cfg(steps: usize, dir: &Path, checkpoint_every: usize) -> MdRunConfig {
    let mut c = MdRunConfig::new(steps, 0.25, 300.0);
    c.equil = 12;
    c.seed = 7;
    c.checkpoint_every = checkpoint_every;
    c.store_dir = Some(dir.to_path_buf());
    c
}

fn frame_bytes(dir: &Path) -> Vec<Vec<u8>> {
    let (store, _) = RunStore::open(dir, "md", Json::Null).expect("open store");
    store.frames().expect("read frames").iter().map(|f| f.encode()).collect()
}

/// Kill a fresh run at production step `kill_step` via the failpoint, then
/// resume it to `steps`. Returns the resumed outcome.
fn kill_and_resume(
    dir: &Path,
    steps: usize,
    checkpoint_every: usize,
    kill_step: u64,
) -> MdRunOutcome {
    let (pos, masses) = geometry();
    failpoint::set("md/step", &format!("err:{kill_step}")).unwrap();
    let died = run_md(&mut provider(), &pos, &masses, &cfg(steps, dir, checkpoint_every));
    failpoint::clear_all();
    assert!(died.is_err(), "failpoint md/step:err:{kill_step} did not kill the run");

    let mut resume = cfg(steps, dir, checkpoint_every);
    resume.resume = true;
    run_md(&mut provider(), &pos, &masses, &resume).expect("resumed run")
}

fn assert_bit_identical(full: &MdRunOutcome, resumed: &MdRunOutcome, what: &str) {
    assert_eq!(full.state.positions.len(), resumed.state.positions.len());
    for (i, (a, b)) in full.state.positions.iter().zip(&resumed.state.positions).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: position {i} diverged");
    }
    for (i, (a, b)) in
        full.state.velocities.iter().zip(&resumed.state.velocities).enumerate()
    {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: velocity {i} diverged");
    }
    assert_eq!(
        full.report.drift_mev_atom_ps.to_bits(),
        resumed.report.drift_mev_atom_ps.to_bits(),
        "{what}: drift fit diverged"
    );
}

/// The core acceptance sweep: kill at several production steps across two
/// checkpoint cadences (including a kill before the first cadence point,
/// which resumes from checkpoint 0) and require bit-identity with the
/// uninterrupted run every time.
#[test]
fn kill_and_resume_is_bit_identical_across_cadences() {
    let _g = guard();
    failpoint::clear_all();
    let (pos, masses) = geometry();
    let steps = 60;

    let ref_dir = tmpdir("reference");
    let full =
        run_md(&mut provider(), &pos, &masses, &cfg(steps, &ref_dir, 10)).expect("full run");
    assert_eq!(full.last_step, steps as u64);
    let ref_frames = frame_bytes(&ref_dir);
    assert_eq!(ref_frames.len(), steps + 1);

    for (cadence, kill_step) in
        [(10, 1), (10, 15), (10, 30), (10, 55), (7, 23), (25, 49)]
    {
        let dir = tmpdir(&format!("kill_c{cadence}_k{kill_step}"));
        let resumed = kill_and_resume(&dir, steps, cadence, kill_step);
        assert_eq!(resumed.last_step, steps as u64);
        assert!(
            resumed.resumed_from.is_some(),
            "cadence {cadence}, kill {kill_step}: run did not resume from a checkpoint"
        );
        assert_bit_identical(&full, &resumed, &format!("cadence {cadence}, kill {kill_step}"));
        // frame byte streams carry step, time, pe, ke, positions, velocities
        // as raw little-endian f64 bits — equality here IS bit-identity of
        // the whole persisted trajectory, energies included
        assert_eq!(
            frame_bytes(&dir),
            ref_frames,
            "cadence {cadence}, kill {kill_step}: persisted trajectory diverged"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
    let _ = std::fs::remove_dir_all(&ref_dir);
}

/// Two crashes in one trajectory: kill, resume, kill again later, resume
/// again — the final trajectory still matches the uninterrupted run bit
/// for bit.
#[test]
fn double_kill_double_resume_is_bit_identical() {
    let _g = guard();
    failpoint::clear_all();
    let (pos, masses) = geometry();
    let steps = 50;

    let ref_dir = tmpdir("double_ref");
    let full =
        run_md(&mut provider(), &pos, &masses, &cfg(steps, &ref_dir, 10)).expect("full run");

    let dir = tmpdir("double_kill");
    // first life: dies at step 18
    failpoint::set("md/step", "err:18").unwrap();
    assert!(run_md(&mut provider(), &pos, &masses, &cfg(steps, &dir, 10)).is_err());
    // second life: resumes from 10, dies at its 22nd own step (step 32)
    failpoint::set("md/step", "err:22").unwrap();
    let mut again = cfg(steps, &dir, 10);
    again.resume = true;
    assert!(run_md(&mut provider(), &pos, &masses, &again).is_err());
    failpoint::clear_all();
    // third life: runs to completion
    let resumed = run_md(&mut provider(), &pos, &masses, &again).expect("final resume");

    assert_eq!(resumed.last_step, steps as u64);
    assert_bit_identical(&full, &resumed, "double kill");
    assert_eq!(frame_bytes(&dir), frame_bytes(&ref_dir), "double kill: trajectory diverged");
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&ref_dir);
}

/// A crash that tears the segment tails on disk (garbage appended to both
/// the frame and checkpoint segments, as a power-cut mid-write would
/// leave): recovery truncates to the last valid record boundary and the
/// resumed run is still bit-identical.
#[test]
fn resume_recovers_torn_tails_bit_identically() {
    let _g = guard();
    failpoint::clear_all();
    let (pos, masses) = geometry();
    let steps = 40;

    let ref_dir = tmpdir("torn_ref");
    let full =
        run_md(&mut provider(), &pos, &masses, &cfg(steps, &ref_dir, 10)).expect("full run");

    let dir = tmpdir("torn");
    failpoint::set("md/step", "err:27").unwrap();
    assert!(run_md(&mut provider(), &pos, &masses, &cfg(steps, &dir, 10)).is_err());
    failpoint::clear_all();

    // tear both segment tails: a partial record header on the frames
    // segment, a few raw bytes on the checkpoints segment
    let tear = |name: &str, junk: &[u8]| {
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join(name))
            .expect("open segment for tearing");
        f.write_all(junk).expect("append torn tail");
    };
    tear(gaq_md::store::FRAMES_SEG, &[0x11, 0x22, 0x33, 0x44, 0x55]);
    tear(gaq_md::store::CHECKPOINTS_SEG, &[0xde, 0xad, 0xbe]);

    let mut resume = cfg(steps, &dir, 10);
    resume.resume = true;
    let resumed =
        run_md(&mut provider(), &pos, &masses, &resume).expect("resume after torn tails");
    assert_eq!(resumed.resumed_from, Some(20), "latest intact checkpoint is step 20");
    assert_eq!(resumed.last_step, steps as u64);
    assert_bit_identical(&full, &resumed, "torn tails");
    assert_eq!(frame_bytes(&dir), frame_bytes(&ref_dir), "torn tails: trajectory diverged");

    // and the recovered store reopens clean: no torn bytes remain
    let (_, report) = RunStore::open(&dir, "md", Json::Null).expect("reopen");
    assert_eq!(report.truncated_bytes(), 0, "recovery left torn bytes behind");
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&ref_dir);
}
