//! Server stress tests: concurrent client threads against a running
//! [`Server`], per-client reply ordering, batch-size bounds, and clean
//! shutdown under load (no deadlock, no hang).

use std::sync::mpsc::RecvTimeoutError;
use std::time::Duration;

use gaq_md::coordinator::{Backend, BatchPolicy, Server, ServerConfig};
use gaq_md::runtime::Manifest;
use gaq_md::util::prng::Rng;

#[test]
fn concurrent_clients_across_all_builtin_variants() {
    let m = Manifest::reference();
    let names: Vec<String> = m.variants.keys().cloned().collect();
    assert!(names.len() >= 7, "builtin roster shrank: {names:?}");
    let max_batch = 4usize;
    let server = Server::start(ServerConfig {
        policy: BatchPolicy {
            max_batch,
            max_wait: Duration::from_micros(200),
            ..BatchPolicy::default()
        },
        variants: names
            .iter()
            .map(|v| {
                (
                    v.clone(),
                    Backend::Reference {
                        artifacts_dir: "/nonexistent/nowhere".into(),
                        variant: v.clone(),
                    },
                    1,
                )
            })
            .collect(),
    })
    .expect("server start");

    let base: Vec<f32> = m.molecule.positions.iter().map(|&x| x as f32).collect();
    let clients = 4usize;
    let per_variant = 3usize;
    let total = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let sub = server.submitter();
                let names = names.clone();
                let base = base.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(c as u64);
                    // submit a burst across every variant, then await in
                    // submit order: each reply must carry its request's id
                    // (per-client ordering) and respect the batch bound
                    let mut pending = Vec::new();
                    for round in 0..per_variant {
                        for v in &names {
                            let mut pos = base.clone();
                            for p in pos.iter_mut() {
                                *p += 0.02 * rng.gaussian() as f32;
                            }
                            let p = sub.submit(v, pos).expect("submit while live");
                            pending.push((v.clone(), round, p));
                        }
                    }
                    let mut done = 0usize;
                    for (v, round, p) in pending {
                        let id = p.id;
                        let r = p
                            .wait_timeout(Duration::from_secs(60))
                            .unwrap_or_else(|e| panic!("client {c} {v} round {round}: {e}"));
                        assert_eq!(r.id, id, "client {c}: reply for the wrong request");
                        assert!(r.error.is_none(), "client {c} {v}: {:?}", r.error);
                        assert!(r.energy_ev.is_finite());
                        assert_eq!(r.forces.len(), 72);
                        assert!(
                            r.batch_size >= 1 && r.batch_size <= max_batch,
                            "batch_size {} out of [1, {max_batch}]",
                            r.batch_size
                        );
                        done += 1;
                    }
                    done
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client panicked")).sum::<usize>()
    });

    assert_eq!(total, clients * per_variant * names.len());
    let metrics = server.metrics();
    assert_eq!(metrics.completed as usize, total);
    assert_eq!(metrics.errors, 0);
    server.shutdown();
}

#[test]
fn shutdown_mid_load_neither_deadlocks_nor_hangs_clients() {
    let server = Server::start(ServerConfig {
        policy: BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            ..BatchPolicy::default()
        },
        variants: vec![("mock".into(), Backend::Mock { n_atoms: 2 }, 2)],
    })
    .expect("server start");

    std::thread::scope(|s| {
        let handles: Vec<_> = (0..4usize)
            .map(|_| {
                let sub = server.submitter();
                s.spawn(move || {
                    let mut accepted = 0usize;
                    let mut answered = 0usize;
                    let mut pending = Vec::new();
                    for i in 0..2000usize {
                        match sub.submit("mock", vec![i as f32; 6]) {
                            Ok(p) => {
                                accepted += 1;
                                pending.push(p);
                            }
                            Err(_) => break, // server shut down mid-load: expected
                        }
                    }
                    for p in pending {
                        match p.wait_timeout(Duration::from_secs(20)) {
                            // flushed before shutdown completed
                            Ok(r) => {
                                assert!(r.error.is_none(), "{:?}", r.error);
                                answered += 1;
                            }
                            // raced the shutdown: dropped cleanly, not hung
                            Err(RecvTimeoutError::Disconnected) => {}
                            Err(RecvTimeoutError::Timeout) => {
                                panic!("client hung waiting for a reply after shutdown")
                            }
                        }
                    }
                    (accepted, answered)
                })
            })
            .collect();

        // let the clients get some load in flight, then pull the plug
        std::thread::sleep(Duration::from_millis(20));
        server.shutdown();

        for h in handles {
            let (accepted, _answered) = h.join().expect("client panicked");
            assert!(accepted > 0, "client never got a request in before shutdown");
        }
    });
}

#[test]
fn burst_load_never_exceeds_max_batch() {
    let max_batch = 5usize;
    let server = Server::start(ServerConfig {
        policy: BatchPolicy {
            max_batch,
            max_wait: Duration::from_micros(500),
            ..BatchPolicy::default()
        },
        variants: vec![("mock".into(), Backend::Mock { n_atoms: 2 }, 2)],
    })
    .expect("server start");

    let total = 3 * 100usize;
    let answered = std::thread::scope(|s| {
        let handles: Vec<_> = (0..3usize)
            .map(|_| {
                let sub = server.submitter();
                s.spawn(move || {
                    let pending: Vec<_> = (0..100usize)
                        .map(|i| sub.submit("mock", vec![i as f32; 6]).expect("submit"))
                        .collect();
                    let mut n = 0usize;
                    for p in pending {
                        let r = p.wait_timeout(Duration::from_secs(30)).expect("reply");
                        assert!(r.error.is_none());
                        assert!(
                            r.batch_size <= max_batch,
                            "executed batch {} > max_batch {max_batch}",
                            r.batch_size
                        );
                        n += 1;
                    }
                    n
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client panicked")).sum::<usize>()
    });
    assert_eq!(answered, total);
    assert_eq!(server.metrics().completed as usize, total);
    server.shutdown();
}
