//! Store durability suite (ISSUE 9, satellite 3): the segment format must
//! round-trip complete records and recover to the last valid record
//! boundary from *any* byte-level damage — truncation at every offset,
//! single-byte corruption anywhere — without ever panicking; the manifest
//! must be byte-identical through a write → read → write cycle; and a
//! crash injected before the manifest rename must leave the previous
//! manifest intact.
//!
//! Tests that activate failpoints serialise on one mutex (the registry is
//! process-global within this test binary).

use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard};

use gaq_md::store::checkpoint::{MdCheckpoint, MdFrame};
use gaq_md::store::manifest::{StoreManifest, MANIFEST_NAME};
use gaq_md::store::{segment, RunStore};
use gaq_md::util::failpoint;
use gaq_md::util::json::Json;
use gaq_md::util::prng::Rng;
use gaq_md::util::proptest::check;

fn guard() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("gaq_durability_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A fixed multi-record image with deliberately varied payload sizes
/// (including an empty payload) plus the record-boundary offsets, 0 first.
fn fixture_image() -> (Vec<Vec<u8>>, Vec<u8>, Vec<usize>) {
    let payloads: Vec<Vec<u8>> = vec![
        Vec::new(),
        b"a".to_vec(),
        (0u8..37).collect(),
        vec![0xff; 64],
        (0u8..23).rev().collect(),
    ];
    let mut image = Vec::new();
    let mut boundaries = vec![0usize];
    for p in &payloads {
        image.extend_from_slice(&segment::encode_record(p));
        boundaries.push(image.len());
    }
    (payloads, image, boundaries)
}

/// Largest record boundary at or below `cut`.
fn boundary_at(boundaries: &[usize], cut: usize) -> usize {
    boundaries.iter().copied().filter(|&b| b <= cut).max().unwrap()
}

/// Exhaustive, not sampled: scanning the image truncated at *every* byte
/// offset yields exactly the complete-record prefix — never a panic, never
/// a partial record, never anything past the last intact boundary.
#[test]
fn scan_truncated_at_every_offset_stops_at_record_boundary() {
    let (payloads, image, boundaries) = fixture_image();
    for cut in 0..=image.len() {
        let s = segment::scan(&image[..cut]);
        let expect = boundary_at(&boundaries, cut);
        assert_eq!(s.valid_len, expect, "cut={cut}");
        let n = boundaries.iter().filter(|&&b| b > 0 && b <= cut).count();
        assert_eq!(s.records.len(), n, "cut={cut}");
        for (i, &(off, len)) in s.records.iter().enumerate() {
            assert_eq!(&image[off..off + len], &payloads[i][..], "cut={cut}, record {i}");
        }
        assert_eq!(s.clean(cut), expect == cut, "cut={cut}");
    }
}

/// The file-backed version of the same sweep: `recover` truncates the torn
/// tail on disk, the surviving records read back exactly, and a second
/// recovery is a no-op (idempotent).
#[test]
fn recover_truncated_file_at_every_offset() {
    let (payloads, image, boundaries) = fixture_image();
    let dir = tmpdir("recover_sweep");
    let path = dir.join("sweep.seg");
    for cut in 0..=image.len() {
        std::fs::write(&path, &image[..cut]).unwrap();
        let rec = segment::recover(&path).expect("recover never errors on truncation");
        let expect = boundary_at(&boundaries, cut);
        assert_eq!(rec.valid_len, expect as u64, "cut={cut}");
        assert_eq!(rec.truncated, (cut - expect) as u64, "cut={cut}");
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            expect as u64,
            "cut={cut}: file not truncated to the valid boundary"
        );
        let n = boundaries.iter().filter(|&&b| b > 0 && b <= cut).count();
        assert_eq!(segment::read_segment(&path).unwrap(), payloads[..n], "cut={cut}");
        let again = segment::recover(&path).expect("second recovery");
        assert_eq!(again.truncated, 0, "cut={cut}: recovery not idempotent");
        assert_eq!(again.records, n, "cut={cut}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Property: flipping any single byte anywhere in a random image is always
/// detected — the scan keeps exactly the records before the damaged one
/// (CRC32C detects all bursts of eight bits or fewer) and never panics.
#[test]
fn prop_single_byte_corruption_truncates_at_damaged_record() {
    check(
        "corrupt byte detected",
        11,
        300,
        |r| {
            let n_records = 1 + r.below(6);
            let payloads: Vec<Vec<u8>> = (0..n_records)
                .map(|_| (0..r.below(40)).map(|_| r.below(256) as u8).collect())
                .collect();
            let flip_record = r.below(n_records);
            let xor = 1 + r.below(255) as u8;
            (payloads, flip_record, xor, r.next_u64())
        },
        |(payloads, flip_record, xor, seed)| {
            let mut image = Vec::new();
            let mut boundaries = vec![0usize];
            for p in payloads {
                image.extend_from_slice(&segment::encode_record(p));
                boundaries.push(image.len());
            }
            // flip one byte inside the chosen record (header or payload)
            let lo = boundaries[*flip_record];
            let hi = boundaries[*flip_record + 1];
            let pos = lo + (seed % (hi - lo) as u64) as usize;
            image[pos] ^= *xor;

            let s = segment::scan(&image);
            if s.records.len() != *flip_record {
                return Err(format!(
                    "flip in record {flip_record} at byte {pos}: scan kept {} records",
                    s.records.len()
                ));
            }
            if s.valid_len != boundaries[*flip_record] {
                return Err(format!(
                    "valid_len {} != boundary {}",
                    s.valid_len, boundaries[*flip_record]
                ));
            }
            Ok(())
        },
    );
}

/// Frame/checkpoint decoding is total: every strict prefix of a valid
/// encoding errors, random garbage errors, and nothing panics.
#[test]
fn frame_and_checkpoint_decode_are_total() {
    let frame = MdFrame {
        step: 42,
        time_fs: 10.5,
        pe_ev: -3.25,
        ke_ev: 0.75,
        positions: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        velocities: vec![0.1, -0.2, 0.3, -0.4, 0.5, -0.6],
    };
    let ck = MdCheckpoint {
        step: 42,
        time_fs: 10.5,
        positions: frame.positions.clone(),
        velocities: frame.velocities.clone(),
        rng: Rng::new(9).state(),
    };
    let fe = frame.encode();
    let ce = ck.encode();
    assert_eq!(MdFrame::decode(&fe).unwrap(), frame);
    assert_eq!(MdCheckpoint::decode(&ce).unwrap(), ck);
    for cut in 0..fe.len() {
        assert!(MdFrame::decode(&fe[..cut]).is_err(), "prefix {cut} decoded");
    }
    for cut in 0..ce.len() {
        assert!(MdCheckpoint::decode(&ce[..cut]).is_err(), "prefix {cut} decoded");
    }
    check(
        "decode total on garbage",
        13,
        300,
        |r| -> Vec<u8> { (0..r.below(120)).map(|_| r.below(256) as u8).collect() },
        |bytes| {
            // any outcome but a panic is acceptable; magic-less garbage errs
            let _ = MdFrame::decode(bytes);
            let _ = MdCheckpoint::decode(bytes);
            Ok(())
        },
    );
}

/// Satellite 3 (manifest half): the canonical manifest serialisation is
/// byte-identical through write → read → write, and its digest is stable.
#[test]
fn manifest_write_read_write_is_byte_identical() {
    let dir = tmpdir("manifest_identity");
    let mut store = RunStore::create(&dir, "md", Json::obj([("kind", Json::str("test"))]))
        .expect("create store");
    for step in 0..3u64 {
        store
            .append_frame(&MdFrame {
                step,
                time_fs: step as f64 * 0.25,
                pe_ev: -1.0,
                ke_ev: 0.5,
                positions: vec![0.1; 6],
                velocities: vec![0.2; 6],
            })
            .unwrap();
    }
    store
        .append_checkpoint(&MdCheckpoint {
            step: 2,
            time_fs: 0.5,
            positions: vec![0.1; 6],
            velocities: vec![0.2; 6],
            rng: Rng::new(1).state(),
        })
        .unwrap();
    store.append_result(&Json::obj([("lee", Json::Num(0.25))])).unwrap();
    store.finalize().unwrap();
    drop(store);

    let path = dir.join(MANIFEST_NAME);
    let first = std::fs::read(&path).unwrap();
    let loaded = StoreManifest::load(&dir).unwrap().expect("manifest exists");
    let digest = loaded.digest();
    loaded.write_atomic(&dir).expect("rewrite");
    let second = std::fs::read(&path).unwrap();
    assert_eq!(first, second, "manifest not byte-identical after read -> write");
    let reloaded = StoreManifest::load(&dir).unwrap().expect("manifest exists");
    assert_eq!(reloaded.digest(), digest, "digest unstable across reload");
    assert_eq!(reloaded.encode().into_bytes(), first, "encode() differs from disk bytes");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A crash injected in the window after the tmp manifest is written but
/// before the rename (the `store/manifest` failpoint) must leave the
/// previously committed manifest untouched and the store openable.
#[test]
fn manifest_crash_before_rename_preserves_committed_manifest() {
    let _g = guard();
    failpoint::clear_all();
    let dir = tmpdir("manifest_crash");
    let mut store = RunStore::create(&dir, "md", Json::Null).expect("create store");
    let ck = |step: u64| MdCheckpoint {
        step,
        time_fs: step as f64,
        positions: vec![0.1; 6],
        velocities: vec![0.2; 6],
        rng: Rng::new(step).state(),
    };
    store
        .append_frame(&MdFrame {
            step: 0,
            time_fs: 0.0,
            pe_ev: -1.0,
            ke_ev: 0.5,
            positions: vec![0.1; 6],
            velocities: vec![0.2; 6],
        })
        .unwrap();
    store.append_checkpoint(&ck(0)).unwrap();
    let committed = std::fs::read(dir.join(MANIFEST_NAME)).unwrap();

    failpoint::set("store/manifest", "err").unwrap();
    let res = store.append_checkpoint(&ck(1));
    failpoint::clear_all();
    assert!(res.is_err(), "manifest commit should have failed at the rename window");
    assert_eq!(
        std::fs::read(dir.join(MANIFEST_NAME)).unwrap(),
        committed,
        "failed commit must not disturb the committed manifest"
    );
    drop(store);

    // the store reopens on the old manifest; both checkpoints' segment
    // records are physically present (appended + synced before the commit),
    // so recovery resumes from the newest durable checkpoint
    let (reopened, _) = RunStore::open(&dir, "md", Json::Null).expect("reopen");
    let latest = reopened.latest_checkpoint().unwrap().expect("a checkpoint");
    assert!(latest.step <= 1);
    let _ = std::fs::remove_dir_all(&dir);
}
